"""Train state: parameters + optimizer moments + step, with sharding specs."""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed import MeshContext, param_sharding_rules, zero_extend
from repro.models import init_params
from repro.optim import OptState, adamw_init


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    step: jnp.ndarray


def init_train_state(cfg: ModelConfig, key: jax.Array) -> TrainState:
    params = init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def abstract_train_state(cfg: ModelConfig) -> TrainState:
    """ShapeDtypeStruct state for AOT lowering (no allocation)."""
    return jax.eval_shape(lambda k: init_train_state(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def state_shardings(
    state: TrainState, mesh_ctx: MeshContext, run: RunConfig,
) -> TrainState:
    """NamedSharding pytree matching a TrainState.

    Parameters follow the tensor/expert-parallel rules; optimizer moments are
    additionally ZeRO-sharded over the data axes when ``run.zero``.
    """
    p_shard = param_sharding_rules(state.params, mesh_ctx)
    if run.fsdp:
        # FSDP: parameters (hence grads) also sharded over the data axes;
        # XLA all-gathers them per scan step and reduce-scatters grads.
        p_shard = jax.tree.map(
            lambda s, p: zero_extend(s, p.shape, mesh_ctx),
            p_shard, state.params)

    def opt_leaf(path_sharding, leaf):
        if run.zero:
            return zero_extend(path_sharding, leaf.shape, mesh_ctx)
        return path_sharding

    mu_shard = jax.tree.map(opt_leaf, p_shard, state.opt.mu)
    nu_shard = jax.tree.map(opt_leaf, p_shard, state.opt.nu)
    from jax.sharding import NamedSharding, PartitionSpec as P
    scalar = NamedSharding(mesh_ctx.mesh, P())
    return TrainState(
        params=p_shard,
        opt=OptState(mu=mu_shard, nu=nu_shard, count=scalar),
        step=scalar,
    )
