"""Train / eval steps with optional gradient accumulation (microbatching).

``make_train_step`` closes over the configs so the jitted signature is
``(state, batch) -> (state, metrics)`` — the function the dry-run lowers.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import forward_train
from repro.optim import adamw_update, cosine_schedule
from repro.train.loss import cross_entropy_loss
from repro.train.state import TrainState


def _loss_fn(params, cfg: ModelConfig, run: RunConfig, batch):
    hidden, extras = forward_train(params, cfg, run, batch["tokens"],
                                   frontend=batch.get("frontend"))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    labels = batch["labels"]
    if hidden.shape[1] != labels.shape[1]:  # vlm: frontend positions unsupervised
        hidden = hidden[:, hidden.shape[1] - labels.shape[1]:]
    loss, acc = cross_entropy_loss(hidden, head, labels, chunk=run.loss_chunk,
                                   vocab=cfg.vocab)
    aux = extras.get("aux", jnp.zeros((), jnp.float32))
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux, "accuracy": acc}


def _grads(params, cfg, run, batch):
    grad_fn = jax.value_and_grad(_loss_fn, has_aux=True)
    (loss, metrics), grads = grad_fn(params, cfg, run, batch)
    return loss, metrics, grads


def train_step(state: TrainState, batch: Dict[str, jnp.ndarray],
               cfg: ModelConfig, run: RunConfig) -> Tuple[TrainState, Dict]:
    if run.microbatch > 1:
        mb = run.microbatch
        b = batch["tokens"].shape[0]
        assert b % mb == 0, f"batch {b} % microbatch {mb} != 0"

        def split(x):
            return x.reshape(mb, b // mb, *x.shape[1:])

        micro = {k: split(v) for k, v in batch.items()}

        def body(carry, mbatch):
            acc_grads, acc_metrics = carry
            _, metrics, grads = _grads(state.params, cfg, run, mbatch)
            acc_grads = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / mb, acc_grads, grads)
            acc_metrics = jax.tree.map(
                lambda a, m: a + m / mb, acc_metrics, metrics)
            return (acc_grads, acc_metrics), None

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        zero_metrics = {"loss": jnp.zeros((), jnp.float32),
                        "aux": jnp.zeros((), jnp.float32),
                        "accuracy": jnp.zeros((), jnp.float32)}
        (grads, metrics), _ = jax.lax.scan(body, (zero_grads, zero_metrics), micro)
    else:
        _, metrics, grads = _grads(state.params, cfg, run, batch)

    if run.grad_compression == "int8":
        # Simulated compressed DP gradient exchange: symmetric int8 per
        # tensor (16x wire format).  On a real pod this wraps the cross-pod
        # reduction; here it quantizes the accumulated gradients so the
        # optimizer sees exactly what a compressed sync would deliver.
        from repro.optim.adamw import compress_int8, decompress_int8

        def _roundtrip(g):
            if g.ndim == 0:
                return g
            q, scale = compress_int8(g.astype(jnp.float32))
            return decompress_int8(q, scale)

        grads = jax.tree.map(_roundtrip, grads)

    lr = cosine_schedule(state.step, run.learning_rate, run.warmup_steps,
                         run.total_steps)
    new_params, new_opt, opt_metrics = adamw_update(
        state.params, grads, state.opt, lr,
        weight_decay=run.weight_decay, grad_clip=run.grad_clip)
    metrics = {**metrics, **opt_metrics}
    return TrainState(params=new_params, opt=new_opt, step=state.step + 1), metrics


def eval_step(state: TrainState, batch, cfg: ModelConfig, run: RunConfig):
    _, metrics = _loss_fn(state.params, cfg, run, batch)
    return metrics


def make_train_step(cfg: ModelConfig, run: RunConfig):
    return functools.partial(train_step, cfg=cfg, run=run)
