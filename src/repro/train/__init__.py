from repro.train.loss import cross_entropy_loss
from repro.train.state import TrainState, init_train_state
from repro.train.step import eval_step, make_train_step, train_step

__all__ = ["TrainState", "cross_entropy_loss", "eval_step", "init_train_state",
           "make_train_step", "train_step"]
