"""Next-token cross entropy, optionally chunked over the sequence so the
(B, S, V) logits tensor is never materialized (a §Perf memory-term lever:
per-chunk peak is (B, chunk, V))."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models.layers import DATA, MODEL


def _ce(logits: jnp.ndarray, labels: jnp.ndarray,
        vocab: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sum CE and correct-token count for (N, V) logits / (N,) labels."""
    logits = logits.astype(jnp.float32)
    if vocab and logits.shape[-1] != vocab:  # mask vocabulary padding
        cols = jnp.arange(logits.shape[-1])
        logits = jnp.where(cols[None, :] < vocab, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss_sum = jnp.sum(lse - picked)
    acc = jnp.sum(jnp.argmax(logits, axis=-1) == labels)
    return loss_sum, acc


def cross_entropy_loss(
    hidden: jnp.ndarray,  # (B, S, d)
    head: jnp.ndarray,  # (d, V)
    labels: jnp.ndarray,  # (B, S)
    chunk: int = 0,
    vocab: int = 0,  # true vocab size when the head is padded
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean next-token CE.  ``chunk``>0 scans over sequence chunks."""
    b, s, d = hidden.shape
    n = b * s
    h2 = hidden.reshape(n, d)
    l2 = labels.reshape(n)

    if chunk <= 0 or n % chunk != 0 or n <= chunk:
        logits = h2.astype(jnp.float32) @ head.astype(jnp.float32)
        logits = constrain(logits, DATA, MODEL)
        loss_sum, acc = _ce(logits, l2, vocab)
        return loss_sum / n, acc / n

    n_chunks = n // chunk
    hc = h2.reshape(n_chunks, chunk, d)
    lc = l2.reshape(n_chunks, chunk)

    def body(carry, inputs):
        loss_sum, acc = carry
        h, lab = inputs
        logits = h.astype(jnp.float32) @ head.astype(jnp.float32)
        logits = constrain(logits, DATA, MODEL)
        ls, ac = _ce(logits, lab, vocab)
        return (loss_sum + ls, acc + ac), None

    (loss_sum, acc), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc))
    return loss_sum / n, acc / n
