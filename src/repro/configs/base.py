"""Model / run configuration dataclasses and the architecture registry."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0
    moe_d_ff: int = 0  # per-expert hidden width
    moe_first_dense: int = 0  # number of leading dense-FFN layers
    moe_group_size: int = 512  # routing group size (GShard-style)
    moe_capacity_factor: float = 1.25

    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # Hybrid (Zamba2): one shared attention block applied every k layers.
    hybrid_attn_every: int = 0

    # Attention details
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: int = 0  # sliding window size; 0 = full causal
    attn_logit_softcap: float = 0.0

    # Encoder-decoder / modality frontends (audio/vlm backbones).
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    frontend: str = "none"  # none | audio_stub | vision_stub
    frontend_len: int = 0  # stub frames / patches per example

    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 16 so the embedding/lm_head shard
        cleanly over the model axis (padded logits are masked to -inf)."""
        return ((self.vocab + 15) // 16) * 16

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing: SSM / hybrid (windowed attn)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND MODEL_FLOPS)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        h_q = self.n_heads * self.d_head
        h_kv = self.n_kv_heads * self.d_head
        attn = d * h_q + 2 * d * h_kv + h_q * d
        per_dense = attn + (3 if self.act == "swiglu" else 2) * d * ff + 2 * d
        total = v * d  # embed
        if not self.tie_embeddings:
            total += d * v
        if self.family == "moe":
            ffe = self.moe_d_ff or ff
            moe = self.moe_experts * 3 * d * ffe + d * self.moe_experts
            shared = self.moe_shared * 3 * d * ffe
            dense_layers = self.moe_first_dense
            moe_layers = self.n_layers - dense_layers
            total += moe_layers * (attn + moe + shared + 2 * d)
            total += dense_layers * per_dense
        elif self.family == "ssm":
            di, n = self.d_inner, self.ssm_state
            per = d * (2 * di + 2 * n + self.ssm_heads) + di * d + 3 * self.ssm_heads
            total += self.n_layers * (per + d)
        elif self.family == "hybrid":
            di, n = self.d_inner, self.ssm_state
            per_mamba = d * (2 * di + 2 * n + self.ssm_heads) + di * d + d
            total += self.n_layers * per_mamba
            shared_blk = (2 * d) * h_q + 2 * (2 * d) * h_kv + h_q * d + 3 * d * ff
            n_inv = self.n_layers // max(self.hybrid_attn_every, 1)
            total += shared_blk + n_inv * (2 * d) * d  # + per-invocation proj
        else:
            layers = self.n_layers + self.n_encoder_layers
            total += layers * per_dense
            if self.encoder_decoder:  # cross attention in decoder layers
                total += self.n_layers * (attn + 2 * d)
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        h_q = self.n_heads * self.d_head
        h_kv = self.n_kv_heads * self.d_head
        attn = d * h_q + 2 * d * h_kv + h_q * d
        ffe = self.moe_d_ff or ff
        active_ffn = (self.moe_top_k + self.moe_shared) * 3 * d * ffe
        dense_layers = self.moe_first_dense
        moe_layers = self.n_layers - dense_layers
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        total += moe_layers * (attn + active_ffn + d * self.moe_experts + 2 * d)
        total += dense_layers * (attn + 3 * d * ff + 2 * d)
        return int(total)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: ``train_*`` lowers train_step, ``decode_*`` /
    ``long_*`` lower serve_step (1 new token against a seq_len KV cache),
    ``prefill_*`` lowers the prefill step."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    """Execution knobs orthogonal to the architecture (perf levers)."""

    attention_impl: str = "chunked"  # chunked | naive  (pallas on real TPU)
    attention_chunk: int = 512
    loss_chunk: int = 0  # 0 = full logits; >0 = vocab-chunked CE over seq chunks
    remat: str = "coarse"  # none | coarse | full
    zero: bool = True  # shard optimizer state over the data axis
    fsdp: bool = False  # additionally shard parameters over the data axis
    grad_reduce: str = "reduce_scatter"  # all_reduce | reduce_scatter
    microbatch: int = 0  # 0 = no gradient accumulation
    seq_shard: bool = False  # sequence parallelism on activations
    # SSD chunk-dim sharding over the model axis (the intra-chunk dual form
    # is chunk-parallel) — §Perf iteration 1; False reproduces the baseline.
    ssd_chunk_shard: bool = True
    # MoE dispatch: "einsum" = GShard dense one-hot matmuls, "gather" =
    # index-based dispatch/combine.  §Perf iterations 2-4: with expert GEMMs
    # correctly group-sharded over data, einsum dispatch has lower HBM/ICI
    # pressure than gather (GSPMD turns the gathers into extra collectives),
    # so einsum stays the default; "gather" is kept as the measured
    # alternative.
    moe_dispatch: str = "einsum"
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    grad_compression: str = "none"  # none | int8
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # Import the per-arch modules lazily on first miss.
        import repro.configs.archs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown architecture '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> Tuple[str, ...]:
    import repro.configs.archs  # noqa: F401
    return tuple(sorted(_REGISTRY))


def tiny_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return replace(
        cfg,
        name=cfg.name + "-tiny",
        n_layers=min(cfg.n_layers, 2),
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=32,
        d_ff=256,
        vocab=512,
        moe_experts=min(cfg.moe_experts, 4) if cfg.moe_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        moe_group_size=64,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_chunk=32,
        hybrid_attn_every=2 if cfg.hybrid_attn_every else 0,
        frontend_len=min(cfg.frontend_len, 16) if cfg.frontend_len else 0,
        moe_first_dense=min(cfg.moe_first_dense, 1),
    )
