"""The ten assigned architectures, exactly as specified in the task sheet.

Each entry records its public source. ``--arch <id>`` selects these in the
launchers; ``tiny_variant`` derives the CPU smoke-test configs.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, register


@register("yi-9b")
def yi_9b() -> ModelConfig:
    # [arXiv:2403.04652; hf] llama-arch GQA. 48L d4096 32H kv4 ff11008 v64000.
    return ModelConfig(
        name="yi-9b", family="dense", n_layers=48, d_model=4096,
        n_heads=32, n_kv_heads=4, d_head=128, d_ff=11008, vocab=64000,
    )


@register("tinyllama-1.1b")
def tinyllama() -> ModelConfig:
    # [arXiv:2401.02385; hf] llama2-arch small. 22L d2048 32H kv4 ff5632 v32000.
    return ModelConfig(
        name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
        n_heads=32, n_kv_heads=4, d_head=64, d_ff=5632, vocab=32000,
    )


@register("starcoder2-15b")
def starcoder2() -> ModelConfig:
    # [arXiv:2402.19173; hf] GQA, RoPE. 40L d6144 48H kv4 ff24576 v49152.
    return ModelConfig(
        name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
        n_heads=48, n_kv_heads=4, d_head=128, d_ff=24576, vocab=49152,
        act="gelu",
    )


@register("qwen3-8b")
def qwen3() -> ModelConfig:
    # [hf:Qwen/Qwen3-8B] qk_norm, GQA. 36L d4096 32H kv8 ff12288 v151936.
    return ModelConfig(
        name="qwen3-8b", family="dense", n_layers=36, d_model=4096,
        n_heads=32, n_kv_heads=8, d_head=128, d_ff=12288, vocab=151936,
        qk_norm=True, rope_theta=1e6,
    )


@register("zamba2-2.7b")
def zamba2() -> ModelConfig:
    # [arXiv:2411.15242; hf] Mamba2 backbone + shared attention block.
    # 54L d2560 32H kv32 ff10240 v32000 ssm_state=64.
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_head=80, d_ff=10240, vocab=32000,
        ssm_state=64, hybrid_attn_every=6,
        window=4096,  # long-context deployment mode for the shared attn block
    )


@register("deepseek-moe-16b")
def deepseek_moe() -> ModelConfig:
    # [arXiv:2401.06066; hf] fine-grained MoE: 2 shared + 64 routed top-6,
    # first layer dense. 28L d2048 16H kv16 expert-ff1408 v102400.
    return ModelConfig(
        name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=16, d_head=128, d_ff=10944, vocab=102400,
        moe_experts=64, moe_top_k=6, moe_shared=2, moe_d_ff=1408,
        moe_first_dense=1,
    )


@register("phi3.5-moe-42b-a6.6b")
def phi35_moe() -> ModelConfig:
    # [hf:microsoft/Phi-3.5-MoE-instruct] 16 experts top-2.
    # 32L d4096 32H kv8 expert-ff6400 v32064.
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_head=128, d_ff=6400, vocab=32064,
        moe_experts=16, moe_top_k=2, moe_shared=0, moe_d_ff=6400,
    )


@register("mamba2-130m")
def mamba2_130m() -> ModelConfig:
    # [arXiv:2405.21060] SSD (state-space duality). 24L d768 attn-free
    # v50280 ssm_state=128.
    return ModelConfig(
        name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
        n_heads=0, n_kv_heads=0, d_head=0, d_ff=0, vocab=50280,
        ssm_state=128, tie_embeddings=True,
    )


@register("whisper-base")
def whisper_base() -> ModelConfig:
    # [arXiv:2212.04356] enc-dec; conv frontend is a stub (input_specs feeds
    # precomputed 80-mel frame embeddings). 6L d512 8H ff2048 v51865.
    return ModelConfig(
        name="whisper-base", family="audio", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, d_head=64, d_ff=2048, vocab=51865,
        encoder_decoder=True, n_encoder_layers=6,
        frontend="audio_stub", frontend_len=1500, act="gelu",
    )


@register("phi-3-vision-4.2b")
def phi3_vision() -> ModelConfig:
    # [hf:microsoft/Phi-3-vision-128k-instruct] phi3-mini backbone + CLIP
    # (stubbed: input_specs provides patch embeddings). 32L d3072 32H kv32
    # ff8192 v32064.
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
        n_heads=32, n_kv_heads=32, d_head=96, d_ff=8192, vocab=32064,
        frontend="vision_stub", frontend_len=576,
    )
