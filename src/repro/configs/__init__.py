from repro.configs.base import (
    ModelConfig,
    RunConfig,
    SHAPES,
    ShapeConfig,
    get_config,
    list_archs,
    tiny_variant,
)

__all__ = [
    "ModelConfig", "RunConfig", "SHAPES", "ShapeConfig",
    "get_config", "list_archs", "tiny_variant",
]
