"""One front door for kernel analysis: ``from repro.api import analyze``.

The facade accepts raw assembly text, a ``.s`` file path, a parsed
:class:`~repro.core.isa.instruction.Kernel`, or an XLA HLO module (text,
parsed, or a ``jax.stages.Compiled``) through the *same* call; the target is
named by an architecture id or alias resolved through the central registry
(:mod:`repro.core.registry`), and the result is always a serializable
:class:`~repro.core.analysis.report.AnalysisReport`::

    from repro.api import analyze

    report = analyze("fadd d0, d0, d1", arch="tx2")     # asm text
    report = analyze("loop.s", arch="cascadelake")      # file path + alias
    report = analyze(hlo_module, arch="tpu-v5e")        # XLA HLO module
    print(report.render("text"))                        # or "json"/"markdown"
    payload = report.to_dict()                          # stable JSON schema

Assembly reports carry two throughput bounds (schema v2): ``tp_block`` (the
paper's uniform-split model, bit-stable) and ``tp_balanced_block`` (the
min-max optimal µ-op→port assignment from
:mod:`repro.core.analysis.scheduler`), with per-port utilization under the
optimal schedule in ``balanced_port_load``.

Analyses share the process-level LRU and one warm :class:`MachineModel` per
architecture, so hot loops repeated across calls are analyzed once.  For
request/response serving (batching, per-request error envelopes), use
:class:`repro.serving.analysis.AnalysisService` — it is built on this facade.
"""

from __future__ import annotations

import os
from typing import Dict, Optional, Union

from repro.core.analysis import Analysis, AnalysisReport, analyze_kernels
from repro.core.isa.instruction import Kernel
from repro.core.registry import (ArchSpec, asm_arch_ids, get_arch,
                                 list_arch_ids, register_arch)

__all__ = [
    "analyze",
    "analyze_raw",
    "AnalysisReport",
    "ArchSpec",
    "get_arch",
    "register_arch",
    "list_arch_ids",
    "asm_arch_ids",
    "AnalysisService",
    "AnalysisRequest",
    "AnalysisResponse",
]

# One warm model per architecture for the process lifetime: its instruction-
# lookup memo then amortizes across every analyze() call.
_MODELS: Dict[str, object] = {}

_ASM_SUFFIXES = (".s", ".asm")
# Suffixes that mark a single-line string source as a file path.  An
# existence probe alone would be cwd-dependent: a one-line kernel text that
# happens to collide with a local filename must not silently become a read.
_PATH_SUFFIXES = _ASM_SUFFIXES + (".hlo", ".txt", ".dump")


def model_for(arch: Union[str, ArchSpec]):
    """The process-wide warm machine model (or TPU chip) for ``arch``."""
    spec = arch if isinstance(arch, ArchSpec) else get_arch(arch)
    model = _MODELS.get(spec.id)
    if model is None:
        model = spec.model_factory()
        _MODELS[spec.id] = model
    return model


def _looks_like_path(text: str) -> bool:
    if "\n" in text:
        return False
    if text.strip().lower().endswith(_PATH_SUFFIXES):
        return True
    # Anything else must both contain a path separator and exist: plain
    # one-line instruction text never does, regardless of the caller's cwd.
    return os.sep in text and os.path.isfile(text)


def _read_if_path(source):
    """Read path-like sources into (text, basename); pass others through."""
    if isinstance(source, os.PathLike) or (
            isinstance(source, str) and _looks_like_path(source)):
        path = os.fspath(source)
        with open(path) as f:
            return f.read(), os.path.basename(path)
    return source, None


def _looks_like_hlo(source) -> bool:
    if hasattr(source, "computations") or hasattr(source, "as_text"):
        return True
    return isinstance(source, str) and source.lstrip().startswith("HloModule")


def _coerce_kernel(source, spec: ArchSpec, name: Optional[str]) -> Kernel:
    if isinstance(source, Kernel):
        if name is not None and source.name != name:
            from dataclasses import replace
            return replace(source, name=name)
        return source
    source, basename = _read_if_path(source)
    if basename is not None:
        return spec.parser(source, name=name or basename)
    if isinstance(source, (str, bytes)):
        text = source.decode() if isinstance(source, bytes) else source
        return spec.parser(text, name=name or "kernel")
    raise TypeError(
        f"cannot analyze {type(source).__name__}: expected asm text, a "
        f"{'/'.join(_ASM_SUFFIXES)} file path, a parsed Kernel, or an HLO "
        f"module")


def analyze_raw(source, arch: str = "tx2", unroll: int = 1,
                name: Optional[str] = None, timeout_s: Optional[float] = None,
                degrade: bool = False, predictors=None,
                diagnose: bool = False) -> Analysis:
    """Like :func:`analyze` but returning the live assembly-pipeline
    :class:`Analysis` (kernel/model objects attached).  Asm targets only.

    ``timeout_s`` puts the analysis under a deadline checked at every stage
    boundary; with ``degrade=True`` an expired deadline (or a failed stage)
    falls down the degradation ladder — full → bracket (no simulator) →
    optimistic-TP-only → parse-only — instead of raising, and the returned
    analysis carries ``degradation`` / ``stages_completed`` saying which
    rung answered.  Without ``degrade``, a timeout raises
    :class:`repro.serving.resilience.StageTimeout`.

    ``predictors`` selects a subset of ``("tp", "cp", "lcd", "sim")``;
    the default computes all four (see
    :func:`repro.core.analysis.normalize_predictors` for the implication
    rules).

    ``diagnose=True`` attaches the structured bottleneck findings
    (:mod:`repro.core.analysis.diagnostics`) to the analysis.
    """
    spec = get_arch(arch)
    if spec.is_hlo:
        raise ValueError(
            f"arch '{spec.id}' is an HLO target; use analyze() for the "
            f"serializable report")
    if unroll < 1:
        raise ValueError(f"unroll must be >= 1, got {unroll}")
    kernel = _coerce_kernel(source, spec, name)
    if timeout_s is None and not degrade:
        return analyze_kernels([kernel], model_for(spec), unroll=unroll,
                               predictors=predictors, diagnose=diagnose)[0]
    from repro.core.analysis import analyze_kernel_ladder
    from repro.serving.resilience import Deadline
    checkpoint = (Deadline.after(timeout_s).check
                  if timeout_s is not None else None)
    return analyze_kernel_ladder(
        kernel, model_for(spec), unroll, checkpoint=checkpoint,
        min_rung="parse_only" if degrade else "full", predictors=predictors,
        diagnose=diagnose)


def analyze(source, arch: str = "tx2", unroll: int = 1,
            name: Optional[str] = None, timeout_s: Optional[float] = None,
            degrade: bool = False, predictors=None,
            diagnose: bool = False) -> AnalysisReport:
    """Analyze a kernel and return the serializable :class:`AnalysisReport`.

    ``source`` may be assembly text, a ``.s``/``.asm`` file path, a parsed
    ``Kernel``, or an HLO module (text starting with ``HloModule``, a parsed
    ``HLOModule``, or a ``Compiled``).  HLO sources are auto-routed to the
    HLO pipeline even when ``arch`` names an asm target's default.

    ``timeout_s`` / ``degrade`` (asm targets only) bound the analysis by a
    deadline and, when degrading, answer with a cheaper ladder rung instead
    of failing — the report's ``degraded`` / ``stages_completed`` fields say
    which rung produced it.

    ``predictors`` (asm targets only) selects a subset of
    ``("tp", "cp", "lcd", "sim")``; the report carries ``None``/zero for
    predictors that were not requested.  HLO sources reject the parameter —
    the simulator and bracket selection are asm-pipeline concepts.

    ``diagnose=True`` (asm targets only) runs the bottleneck-diagnostics
    pass and fills the report's schema-v4 ``findings``; the default leaves
    them ``None`` (pass not run).
    """
    spec = get_arch(arch)
    # Read path sources up front so the HLO sniff sees file *contents*, not
    # the path string (an .hlo file must auto-route even under an asm arch).
    source, basename = _read_if_path(source)
    if basename is not None:
        name = name or basename
    if spec.is_hlo and not _looks_like_hlo(source):
        got = (f"text starting {source.strip()[:40]!r}"
               if isinstance(source, str) else type(source).__name__)
        raise ValueError(
            f"arch '{spec.id}' expects an HLO module (text starting with "
            f"'HloModule', a parsed HLOModule, a Compiled, or a file path); "
            f"got {got}")
    if spec.is_hlo or _looks_like_hlo(source):
        if predictors is not None:
            raise ValueError(
                "predictors= applies to asm targets only; HLO analyses "
                "always report the roofline/CP/LCD set")
        if diagnose:
            raise ValueError(
                "diagnose= applies to asm targets only; the diagnostics "
                "pass reads the asm pipeline's port/LCD/simulator results")
        chip = model_for(spec) if spec.is_hlo else None
        hlo_arch = spec.id if spec.is_hlo else "tpu-v5e"
        return AnalysisReport.from_hlo(source, chip=chip, arch=hlo_arch,
                                       name=name)
    return analyze_raw(source, arch=arch, unroll=unroll, name=name,
                       timeout_s=timeout_s, degrade=degrade,
                       predictors=predictors, diagnose=diagnose).to_report()


def __getattr__(attr):
    # Service classes are exposed lazily: ``repro.serving`` pulls in the jax
    # token engine, which plain analyze() callers should not pay for.
    if attr in ("AnalysisService", "AnalysisRequest", "AnalysisResponse"):
        from repro.serving import analysis as _serving
        return getattr(_serving, attr)
    raise AttributeError(f"module 'repro.api' has no attribute '{attr}'")
