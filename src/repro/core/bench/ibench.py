"""Semi-automatic benchmark pipeline (paper §II-B, the ibench/asmbench role).

The paper populates its instruction database by generating two synthetic
micro-benchmarks per instruction form:

* **latency**: a serial dependency chain (each op consumes the previous
  result), so steady-state time/op = latency;
* **throughput**: independent parallel chains, so steady-state time/op =
  inverse throughput.

The same methodology is re-targeted here at JAX primitives: we cannot execute
x86/ARM assembly in this container (those DBs come from public data, exactly
like the paper's uops.info/Agner-Fog path), but the pipeline itself is fully
exercised against jnp ops and is what populates the measured per-op cost
table used to sanity-check the HLO machine model (``repro.core.hlo``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.machine.model import DBEntry


@dataclass
class BenchmarkResult:
    name: str
    latency_us: float  # time per op in the serial-chain benchmark
    inverse_throughput_us: float  # time per op with independent chains
    chain_length: int
    n_parallel: int

    @property
    def ilp_speedup(self) -> float:
        if self.inverse_throughput_us == 0:
            return float("inf")
        return self.latency_us / self.inverse_throughput_us


def _time_fn(fn: Callable, *args, repeats: int = 5) -> float:
    """Best-of-N wall time of an already-jitted function, in seconds."""
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measure_latency(
    op: Callable[[jnp.ndarray], jnp.ndarray],
    shape: Tuple[int, ...] = (128, 128),
    dtype=jnp.float32,
    chain_length: int = 64,
) -> float:
    """Serial dependency chain: y = op(op(...op(x)...)). µs per op."""

    def chained(x):
        def body(carry, _):
            return op(carry), None
        y, _ = jax.lax.scan(body, x, None, length=chain_length)
        return y

    fn = jax.jit(chained)
    x = jnp.ones(shape, dtype)
    total = _time_fn(fn, x)
    return total / chain_length * 1e6


def measure_throughput(
    op: Callable[[jnp.ndarray], jnp.ndarray],
    shape: Tuple[int, ...] = (128, 128),
    dtype=jnp.float32,
    chain_length: int = 64,
    n_parallel: int = 8,
) -> float:
    """``n_parallel`` independent chains (vmapped): exposes ILP. µs per op."""

    def chained(x):
        def body(carry, _):
            return op(carry), None
        y, _ = jax.lax.scan(body, x, None, length=chain_length)
        return y

    fn = jax.jit(jax.vmap(chained))
    x = jnp.ones((n_parallel, *shape), dtype)
    total = _time_fn(fn, x)
    return total / (chain_length * n_parallel) * 1e6


def populate_entry(
    name: str,
    op: Callable[[jnp.ndarray], jnp.ndarray],
    shape: Tuple[int, ...] = (128, 128),
    dtype=jnp.float32,
    chain_length: int = 32,
    n_parallel: int = 4,
    ports: Tuple[str, ...] = ("VPU",),
) -> Tuple[BenchmarkResult, DBEntry]:
    """Run both benchmarks and emit a database entry (µs-denominated).

    This is the ibench import path of the paper: measurement → DB record.
    """
    lat = measure_latency(op, shape, dtype, chain_length)
    tput = measure_throughput(op, shape, dtype, chain_length, n_parallel)
    result = BenchmarkResult(
        name=name,
        latency_us=lat,
        inverse_throughput_us=tput,
        chain_length=chain_length,
        n_parallel=n_parallel,
    )
    share = tput / len(ports)
    entry = DBEntry(
        latency=lat,
        pressure={p: share for p in ports},
        note=f"measured via ibench pipeline ({chain_length}-chain x {n_parallel})",
    )
    return result, entry
