from repro.core.bench.ibench import (
    BenchmarkResult,
    measure_latency,
    measure_throughput,
    populate_entry,
)

__all__ = ["BenchmarkResult", "measure_latency", "measure_throughput", "populate_entry"]
