"""Central architecture / ISA registry: the single source of truth that maps
an architecture id (or alias) to its ISA, parser, machine-model factory,
clock frequency, and built-in sample kernel.

Before this registry the arch → (parser, model) tables were duplicated in
``repro.serving.analysis``, ``examples/analyze_kernel.py``, and the serve CLI,
each with a different subset of machines.  Everything that needs to turn an
``--arch`` string into an analysis pipeline — the ``repro.api`` facade, the
serving layer, the examples — resolves through :func:`get_arch` instead.

Alias matching is case-insensitive and ignores ``-``/``_``/spaces, so
``csx``, ``CLX``, ``cascadelake``, and ``cascade-lake`` all name the Cascade
Lake model.  Out-of-tree machines can be added at runtime with
:func:`register_arch`.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.isa import parse_aarch64, parse_x86
from repro.core.machine import (cascade_lake, neoverse_n1, thunderx2, zen,
                                zen2)
from repro.core.validation import GS_CLX_ASM, GS_TX2_ASM, GS_ZEN_ASM

#: ISA id used by HLO-module entries (the TPU adaptation of the paper).
HLO_ISA = "hlo"


@dataclass(frozen=True)
class ArchSpec:
    """Everything needed to analyze a kernel for one target architecture."""

    id: str
    isa: str  # "x86" | "aarch64" | "hlo"
    model_factory: Callable[[], object]  # MachineModel (asm) or TPUChip (hlo)
    frequency_ghz: float
    parser: Optional[Callable] = None  # (text, name=...) -> Kernel
    aliases: Tuple[str, ...] = ()
    description: str = ""
    sample_asm: Optional[str] = None  # built-in demo kernel (validation suite)

    @property
    def is_hlo(self) -> bool:
        return self.isa == HLO_ISA


_REGISTRY: Dict[str, ArchSpec] = {}
# normalized name (id or alias) -> canonical id
_NAMES: Dict[str, str] = {}


def _normalize(name: str) -> str:
    return re.sub(r"[-_ .]", "", name.strip().lower())


def register_arch(spec: ArchSpec, overwrite: bool = False) -> ArchSpec:
    """Add an architecture to the registry (id + all aliases resolvable).

    Atomic: all names are validated before any registry state changes, so a
    conflicting alias leaves the registry untouched.
    """
    keys = [_normalize(alias) for alias in (spec.id,) + spec.aliases]
    if not overwrite:
        for alias, key in zip((spec.id,) + spec.aliases, keys):
            owner = _NAMES.get(key)
            if owner is not None and owner != spec.id:
                raise ValueError(
                    f"arch name '{alias}' already registered for '{owner}'")
    for key in keys:
        _NAMES[key] = spec.id
    _REGISTRY[spec.id] = spec
    return spec


def get_arch(name: str) -> ArchSpec:
    """Resolve an architecture id or alias to its :class:`ArchSpec`."""
    arch_id = _NAMES.get(_normalize(str(name)))
    if arch_id is None:
        known = ", ".join(
            f"{s.id} ({'/'.join(s.aliases)})" if s.aliases else s.id
            for s in sorted(_REGISTRY.values(), key=lambda s: s.id))
        raise ValueError(f"unknown arch '{name}'; known: {known}")
    return _REGISTRY[arch_id]


def list_arch_ids(isa: Optional[str] = None) -> List[str]:
    """Canonical architecture ids, optionally filtered by ISA."""
    return sorted(s.id for s in _REGISTRY.values()
                  if isa is None or s.isa == isa)


def asm_arch_ids() -> List[str]:
    """Ids of the assembly (non-HLO) targets — the CLI-facing set."""
    return sorted(s.id for s in _REGISTRY.values() if not s.is_hlo)


def registry_snapshot() -> Tuple[Dict[str, str], Dict[str, ArchSpec]]:
    """Copies of the (alias → id, id → spec) tables, for consistency checks.

    The machine-model linter (:mod:`repro.core.machine.lint`) walks these to
    find dangling aliases and resolution cycles without reaching into the
    module privates; mutating the returned dicts does not affect the
    registry.
    """
    return dict(_NAMES), dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Built-in targets (paper machines + the TPU HLO adaptation)
# ---------------------------------------------------------------------------

register_arch(ArchSpec(
    id="tx2", isa="aarch64", model_factory=thunderx2, frequency_ghz=2.2,
    parser=parse_aarch64, aliases=("thunderx2",),
    description="Marvell ThunderX2 (ARMv8.1)", sample_asm=GS_TX2_ASM,
))
register_arch(ArchSpec(
    id="csx", isa="x86", model_factory=cascade_lake, frequency_ghz=2.5,
    parser=parse_x86, aliases=("clx", "cascadelake", "cascade-lake"),
    description="Intel Cascade Lake SP", sample_asm=GS_CLX_ASM,
))
register_arch(ArchSpec(
    id="zen", isa="x86", model_factory=zen, frequency_ghz=2.3,
    parser=parse_x86, aliases=("zen1", "epyc"),
    description="AMD Zen (EPYC 7451)", sample_asm=GS_ZEN_ASM,
))
register_arch(ArchSpec(
    id="zen2", isa="x86", model_factory=zen2, frequency_ghz=3.4,
    parser=parse_x86, aliases=("rome",),
    description="AMD Zen 2 (Rome)", sample_asm=GS_ZEN_ASM,
))
register_arch(ArchSpec(
    id="n1", isa="aarch64", model_factory=neoverse_n1, frequency_ghz=2.5,
    parser=parse_aarch64, aliases=("neoverse-n1", "graviton2"),
    description="Arm Neoverse N1", sample_asm=GS_TX2_ASM,
))


def _tpu_v5e():
    from repro.core.hlo import TPU_V5E
    return TPU_V5E


register_arch(ArchSpec(
    id="tpu-v5e", isa=HLO_ISA, model_factory=_tpu_v5e, frequency_ghz=0.0,
    aliases=("tpu", "v5e", "tpu_v5e"),
    description="TPU v5e engine model (XLA HLO modules)",
))
