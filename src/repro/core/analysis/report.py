"""Serializable analysis report: the wire format of the public API.

:class:`AnalysisReport` is a plain-data snapshot of one TP/CP/LCD analysis —
per-instruction rows (port pressure, CP / LCD membership), the per-port
totals, and the [TP, LCD, CP] prediction bracket — detached from the live
``Kernel`` / ``MachineModel`` objects so it can round-trip through JSON
(``to_dict`` / ``from_dict``) and be rendered by any registered renderer
(``render("text" | "json" | "markdown")``, see ``repro.core.analysis.render``).

Both front-ends produce it: :meth:`AnalysisReport.from_analysis` wraps the
assembly pipeline's ``Analysis`` (``kind="asm"``, cycles per iteration), and
:meth:`AnalysisReport.from_hlo` wraps the TPU adaptation (``kind="hlo"``,
seconds per step) — same schema, same bracket keys, so a downstream tool can
consume an HLO while-body and an asm loop identically.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.analysis.diagnostics import Finding

#: v2 adds the balanced (min-max optimal port assignment) throughput bound:
#: ``tp_balanced_block``, ``balanced_port_load``, ``balanced_bottleneck``.
#: v1 payloads load with ``balanced == optimistic`` (v1 predates the
#: scheduler, when the uniform split was the only model).
#:
#: v2 additive (same version, defaulted on load): ``degraded``,
#: ``degradation``, ``stages_completed`` — the serving path's degradation
#: ladder marks partial answers (``tp_only`` / ``parse_only`` rungs) so a
#: caller can always tell a degraded report from a full one.
#:
#: v3 adds the window-limited OoO simulator's point prediction:
#: ``sim_block`` (clamped into the [TP, CP] bracket; ``None`` when the
#: simulator did not run), ``sim_raw_block`` (unclamped steady state),
#: ``sim_converged`` / ``sim_copies`` / ``sim_clamped`` / ``sim_limiter``,
#: and ``sim_window`` (the per-arch window parameters used).  v1/v2
#: payloads load with ``sim_block=None``.
#:
#: v4 adds ``findings`` — the structured bottleneck diagnostics
#: (:mod:`repro.core.analysis.diagnostics`).  ``None`` means the diagnostics
#: pass did not run (absence ≠ zero findings: an empty list is a clean bill
#: of health, ``None`` says nobody looked); v1/v2/v3 payloads load with
#: ``findings=None``.
SCHEMA_VERSION = 4

#: All pipeline stages, the ``stages_completed`` value of a full report.
FULL_STAGES = ("resolve", "tp", "dag", "cp", "lcd", "sim")

#: What a full report completed before the simulator existed (schema <= 2);
#: the ``stages_completed`` default for payloads that predate the field.
_LEGACY_FULL_STAGES = ("resolve", "tp", "dag", "cp", "lcd")

#: Bracket keys shared by both kinds — the paper's [TP, CP] runtime bracket
#: with the LCD as the expected value.
BRACKET_KEYS = ("lower_bound_tp", "expected_lcd", "upper_bound_cp")


@dataclass(frozen=True)
class InstructionRow:
    """One analyzed instruction (asm) or critical-path op (hlo)."""

    index: int
    line_number: int
    asm: str  # raw assembly text / HLO op name
    mnemonic: str
    latency: float  # node latency in cycles (asm) or seconds (hlo)
    port_pressure: Dict[str, float]
    on_critical_path: bool
    on_lcd: bool


@dataclass(frozen=True)
class LCDChainRow:
    """One cyclic loop-carried chain (one period's length)."""

    length: float
    members: Tuple = ()  # instruction indices (asm) / op names (hlo)
    carried_by: object = None  # closing instr index (asm) / tuple index (hlo)


@dataclass(frozen=True)
class AnalysisReport:
    """Typed, JSON-stable result of one kernel analysis."""

    kind: str  # "asm" | "hlo"
    kernel_name: str
    arch: str
    isa: str
    unroll: int
    frequency_ghz: float
    unit: str  # "cy/it" (asm) | "s" (hlo)
    ports: Tuple[str, ...]
    rows: Tuple[InstructionRow, ...]
    port_pressure: Dict[str, float]  # per-block totals, model port order
    bottleneck_port: str
    tp_block: float  # optimistic bound, per assembly-block / per step
    cp_block: float
    lcd_block: float
    lcd_chains: Tuple[LCDChainRow, ...] = ()
    # Balanced bound: min-max optimal µ-op→port assignment (schema v2).
    tp_balanced_block: float = 0.0
    balanced_port_load: Dict[str, float] = field(default_factory=dict)
    balanced_bottleneck: str = ""
    # Degradation ladder (schema v2, additive): a degraded report carries
    # only the numbers its rung computed; the rest are 0.0.
    degraded: bool = False
    degradation: str = "full"  # "full" | "bracket" | "tp_only" | "parse_only"
    stages_completed: Tuple[str, ...] = FULL_STAGES
    # Window-limited OoO simulator point prediction (schema v3).  Unlike the
    # bounds, absence is meaningful (not requested / no window model / a
    # bracket-rung answer), so the headline value is Optional rather than 0.0.
    sim_block: Optional[float] = None
    sim_raw_block: Optional[float] = None  # unclamped steady-state measure
    sim_converged: bool = False
    sim_copies: int = 0
    sim_clamped: str = ""  # "" | "tp" | "cp"
    sim_limiter: str = ""  # dominant binding constraint at steady state
    sim_window: Dict[str, int] = field(default_factory=dict)
    # Structured bottleneck diagnostics (schema v4).  ``None`` = the
    # diagnostics pass did not run; ``()`` = it ran and found nothing.
    findings: Optional[Tuple[Finding, ...]] = None
    schema_version: int = SCHEMA_VERSION

    # -- derived -----------------------------------------------------------

    @property
    def tp_per_it(self) -> float:
        return self.tp_block / self.unroll

    @property
    def cp_per_it(self) -> float:
        return self.cp_block / self.unroll

    @property
    def lcd_per_it(self) -> float:
        return self.lcd_block / self.unroll

    @property
    def tp_balanced_per_it(self) -> float:
        return self.tp_balanced_block / self.unroll

    @property
    def sim_per_it(self) -> Optional[float]:
        if self.sim_block is None:
            return None
        return self.sim_block / self.unroll

    def prediction_bracket(self) -> Dict[str, float]:
        """[TP, CP] runtime bracket with the LCD as the expected value."""
        return {
            "lower_bound_tp": self.tp_per_it,
            "expected_lcd": self.lcd_per_it,
            "upper_bound_cp": self.cp_per_it,
        }

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> Dict:
        """Plain-JSON form; ``from_dict(to_dict())`` is bit-identical."""
        return {
            "schema_version": self.schema_version,
            "kind": self.kind,
            "kernel_name": self.kernel_name,
            "arch": self.arch,
            "isa": self.isa,
            "unroll": self.unroll,
            "frequency_ghz": self.frequency_ghz,
            "unit": self.unit,
            "ports": list(self.ports),
            "port_pressure": dict(self.port_pressure),
            "bottleneck_port": self.bottleneck_port,
            "tp_block": self.tp_block,
            "cp_block": self.cp_block,
            "lcd_block": self.lcd_block,
            "tp_balanced_block": self.tp_balanced_block,
            "balanced_port_load": dict(self.balanced_port_load),
            "balanced_bottleneck": self.balanced_bottleneck,
            "degraded": self.degraded,
            "degradation": self.degradation,
            "stages_completed": list(self.stages_completed),
            "sim_block": self.sim_block,
            "sim_raw_block": self.sim_raw_block,
            "sim_converged": self.sim_converged,
            "sim_copies": self.sim_copies,
            "sim_clamped": self.sim_clamped,
            "sim_limiter": self.sim_limiter,
            "sim_window": dict(self.sim_window),
            "findings": ([f.to_dict() for f in self.findings]
                         if self.findings is not None else None),
            "prediction_bracket": self.prediction_bracket(),
            "rows": [asdict(r) for r in self.rows],
            "lcd_chains": [
                {"length": c.length, "members": list(c.members),
                 "carried_by": c.carried_by}
                for c in self.lcd_chains
            ],
        }

    @classmethod
    def from_dict(cls, data: Dict) -> "AnalysisReport":
        version = data.get("schema_version", SCHEMA_VERSION)
        if version > SCHEMA_VERSION:
            raise ValueError(
                f"report schema v{version} is newer than supported "
                f"v{SCHEMA_VERSION}")
        rows = tuple(
            InstructionRow(
                index=r["index"], line_number=r["line_number"], asm=r["asm"],
                mnemonic=r["mnemonic"], latency=r["latency"],
                port_pressure=dict(r["port_pressure"]),
                on_critical_path=r["on_critical_path"], on_lcd=r["on_lcd"],
            ) for r in data["rows"])
        chains = tuple(
            LCDChainRow(length=c["length"], members=tuple(c["members"]),
                        carried_by=c["carried_by"])
            for c in data.get("lcd_chains", ()))
        return cls(
            kind=data["kind"], kernel_name=data["kernel_name"],
            arch=data["arch"], isa=data["isa"], unroll=data["unroll"],
            frequency_ghz=data["frequency_ghz"], unit=data["unit"],
            ports=tuple(data["ports"]),
            rows=rows, port_pressure=dict(data["port_pressure"]),
            bottleneck_port=data["bottleneck_port"],
            tp_block=data["tp_block"], cp_block=data["cp_block"],
            lcd_block=data["lcd_block"], lcd_chains=chains,
            # v1 compatibility: before the scheduler, the uniform split was
            # the only port model, so balanced defaults to optimistic.
            tp_balanced_block=data.get("tp_balanced_block",
                                       data["tp_block"]),
            balanced_port_load=dict(data.get("balanced_port_load",
                                             data["port_pressure"])),
            balanced_bottleneck=data.get("balanced_bottleneck",
                                         data["bottleneck_port"]),
            # Additive degradation fields: payloads written before the
            # ladder are, by construction, full reports.
            degraded=data.get("degraded", False),
            degradation=data.get("degradation", "full"),
            stages_completed=tuple(data.get("stages_completed",
                                            _LEGACY_FULL_STAGES)),
            # v3 simulator fields: pre-simulator payloads have no point
            # prediction, which None (not 0.0) states faithfully.
            sim_block=data.get("sim_block"),
            sim_raw_block=data.get("sim_raw_block"),
            sim_converged=data.get("sim_converged", False),
            sim_copies=data.get("sim_copies", 0),
            sim_clamped=data.get("sim_clamped", ""),
            sim_limiter=data.get("sim_limiter", ""),
            sim_window=dict(data.get("sim_window", {})),
            # v4 diagnostics: for older payloads, None states faithfully
            # that the pass never ran (absence ≠ zero findings).
            findings=(tuple(Finding.from_dict(f)
                            for f in data["findings"])
                      if data.get("findings") is not None else None),
            schema_version=version,
        )

    def to_json(self, **kwargs) -> str:
        return json.dumps(self.to_dict(), **kwargs)

    @classmethod
    def from_json(cls, text: str) -> "AnalysisReport":
        return cls.from_dict(json.loads(text))

    def render(self, fmt: str = "text") -> str:
        from repro.core.analysis.render import render
        return render(self, fmt)

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_analysis(cls, analysis) -> "AnalysisReport":
        """Snapshot an assembly-pipeline :class:`Analysis`.

        Degraded analyses (``tp_only`` / ``parse_only`` ladder rungs) carry
        only what their rung computed: a ``tp_only`` report has rows and
        optimistic port pressure but zero CP/LCD, a ``parse_only`` report
        has rows straight from the parsed forms with no pressure at all.
        """
        tp, cp, lcd = analysis.tp, analysis.cp, analysis.lcd
        cp_on = cp.on_path if cp is not None else frozenset()
        lcd_on = lcd.on_longest if lcd is not None else frozenset()
        rows = []
        if tp is not None:
            for idx, (cost, pressure) in enumerate(tp.per_instruction):
                rows.append(InstructionRow(
                    index=idx,
                    line_number=cost.form.line_number,
                    asm=cost.form.raw.strip(),
                    mnemonic=cost.form.mnemonic,
                    latency=cost.entry.latency,
                    port_pressure={p: cy for p, cy in pressure.items()},
                    on_critical_path=idx in cp_on,
                    on_lcd=idx in lcd_on,
                ))
        else:  # parse_only: rows from the parsed forms, no DB resolution
            for idx, form in enumerate(analysis.kernel):
                rows.append(InstructionRow(
                    index=idx,
                    line_number=form.line_number,
                    asm=form.raw.strip(),
                    mnemonic=form.mnemonic,
                    latency=0.0,
                    port_pressure={},
                    on_critical_path=False,
                    on_lcd=False,
                ))
        chains = tuple(
            LCDChainRow(length=c.length, members=tuple(c.instr_indices),
                        carried_by=c.carried_by)
            for c in lcd.chains) if lcd is not None else ()
        model = analysis.model
        sim = getattr(analysis, "sim", None)
        return cls(
            kind="asm",
            kernel_name=analysis.kernel.name,
            arch=model.name,
            isa=model.isa,
            unroll=analysis.unroll,
            frequency_ghz=model.frequency_ghz,
            unit="cy/it",
            ports=tuple(model.ports),
            rows=tuple(rows),
            port_pressure={p: tp.port_pressure.get(p, 0.0)
                           for p in model.ports} if tp is not None
            else {p: 0.0 for p in model.ports},
            bottleneck_port=tp.bottleneck_port if tp is not None else "",
            tp_block=tp.block_throughput if tp is not None else 0.0,
            cp_block=cp.length if cp is not None else 0.0,
            lcd_block=lcd.longest if lcd is not None else 0.0,
            lcd_chains=chains,
            tp_balanced_block=tp.balanced_throughput if tp is not None else 0.0,
            balanced_port_load={p: tp.balanced_port_load.get(p, 0.0)
                                for p in model.ports} if tp is not None
            else {p: 0.0 for p in model.ports},
            balanced_bottleneck=tp.balanced_bottleneck if tp is not None else "",
            degraded=analysis.degraded,
            degradation=analysis.degradation,
            stages_completed=tuple(analysis.stages_completed),
            sim_block=sim.cy_per_block if sim is not None else None,
            sim_raw_block=sim.raw_cy_per_block if sim is not None else None,
            sim_converged=sim.converged if sim is not None else False,
            sim_copies=sim.copies if sim is not None else 0,
            sim_clamped=sim.clamped_to if sim is not None else "",
            sim_limiter=sim.limiter if sim is not None else "",
            sim_window=(sim.window.to_dict()
                        if sim is not None and sim.window is not None else {}),
            findings=getattr(analysis, "findings", None),
        )

    @classmethod
    def from_hlo(cls, source, chip=None, arch: str = "tpu-v5e",
                 name: Optional[str] = None) -> "AnalysisReport":
        """Analyze an HLO module (text, parsed, or Compiled) into the same
        report shape: roofline bound as TP, longest while-carried chain as
        LCD, def-use critical path as CP — all in seconds per step."""
        from repro.core.hlo import (TPU_V5E, hlo_critical_path,
                                    hlo_loop_carried, parse_hlo)
        from repro.core.hlo.costs import HLOCostModel
        from repro.core.hlo.roofline import collective_stats

        chip = chip or TPU_V5E
        if hasattr(source, "as_text"):
            source = source.as_text()
        module = source if hasattr(source, "computations") else parse_hlo(source)
        if not module.computations or \
                module.entry_name not in module.computations:
            raise ValueError(
                f"not a valid HLO module: no entry computation parsed "
                f"(module name {module.name!r}) — is the dump truncated?")

        cost = HLOCostModel(module, chip)
        flops = cost.computation_flops(module.entry_name)
        hbm_bytes = sum(cost.op_bytes(op, module.entry)
                        for op in module.entry.ops)
        stats = collective_stats(module, chip)
        terms = chip.port_pressure(float(flops), float(hbm_bytes),
                                   stats.total_bytes)
        cp = hlo_critical_path(module, chip)
        lcd = hlo_loop_carried(module, chip)

        longest = lcd.longest
        lcd_ops = set(longest.ops) if longest is not None else set()
        rows = tuple(
            InstructionRow(
                index=i, line_number=-1, asm=node.op_name,
                mnemonic=node.opcode, latency=node.seconds, port_pressure={},
                on_critical_path=True, on_lcd=node.op_name in lcd_ops,
            ) for i, node in enumerate(cp.path))
        chains = tuple(
            LCDChainRow(length=c.total_seconds, members=tuple(c.ops),
                        carried_by=c.tuple_index)
            for c in lcd.chains)
        bottleneck = max(terms, key=lambda k: terms[k]) if terms else ""
        return cls(
            kind="hlo",
            kernel_name=name or module.name,
            arch=arch,
            isa="hlo",
            unroll=1,
            frequency_ghz=0.0,
            unit="s",
            ports=tuple(terms),
            rows=rows,
            port_pressure=dict(terms),
            bottleneck_port=bottleneck,
            tp_block=terms.get(bottleneck, 0.0),
            cp_block=cp.seconds,
            lcd_block=longest.total_seconds if longest is not None else 0.0,
            lcd_chains=chains,
            # Roofline terms are engine-pinned: no assignment freedom.
            tp_balanced_block=terms.get(bottleneck, 0.0),
            balanced_port_load=dict(terms),
            balanced_bottleneck=bottleneck,
            # The OoO simulator is an asm-pipeline concept; HLO reports
            # complete the legacy stage set and carry no point prediction.
            stages_completed=_LEGACY_FULL_STAGES,
        )
