"""Pluggable renderers for :class:`~repro.core.analysis.report.AnalysisReport`.

Built-ins: ``text`` (the condensed Table-II-style report, byte-identical to
the legacy ``Analysis.report()`` output for assembly kernels), ``json`` (the
stable ``to_dict`` schema), and ``markdown``.  Register additional formats
with :func:`register_renderer`.
"""

from __future__ import annotations

from typing import Callable, Dict, List

RENDERERS: Dict[str, Callable] = {}


def register_renderer(name: str, fn: Callable) -> None:
    RENDERERS[name] = fn


def render(report, fmt: str = "text") -> str:
    try:
        renderer = RENDERERS[fmt]
    except KeyError:
        raise ValueError(
            f"unknown report format '{fmt}'; known: {sorted(RENDERERS)}"
        ) from None
    return renderer(report)


# ---------------------------------------------------------------------------
# text
# ---------------------------------------------------------------------------


def _shown_ports(report) -> List[str]:
    return [p for p in report.ports
            if report.port_pressure.get(p, 0.0) > 0.0
            or report.balanced_port_load.get(p, 0.0) > 0.0]


def _text_asm(report) -> str:
    shown_ports = _shown_ports(report)
    head = " ".join(f"{p:>5}" for p in shown_ports)
    lines: List[str] = []
    lines.append(f"OSACA analysis  kernel={report.kernel_name}  "
                 f"arch={report.arch}  unroll={report.unroll}x")
    lines.append(f"{head} | {'LCD':>5} {'CP':>5} | {'LN':>4} | assembly")
    lines.append("-" * (len(head) + 32))
    for row in report.rows:
        cells = " ".join(
            f"{row.port_pressure.get(p, 0.0):5.2f}"
            if row.port_pressure.get(p, 0.0) else "     "
            for p in shown_ports
        )
        lcd_mark = f"{row.latency:5.1f}" if row.on_lcd else "     "
        cp_mark = f"{row.latency:5.1f}" if row.on_critical_path else "     "
        lines.append(f"{cells} | {lcd_mark} {cp_mark} | {row.line_number:>4} | "
                     f"{row.asm}")
    lines.append("-" * (len(head) + 32))
    totals = " ".join(f"{report.port_pressure.get(p, 0.0):5.2f}"
                      for p in shown_ports)
    lines.append(f"{totals} | {report.lcd_block:5.1f} {report.cp_block:5.1f} | "
                 f"(per {report.unroll}x-unrolled block)")
    per_it = " ".join(
        f"{report.port_pressure.get(p, 0.0) / report.unroll:5.2f}"
        for p in shown_ports
    )
    lines.append(f"{per_it} | {report.lcd_per_it:5.1f} {report.cp_per_it:5.1f} | "
                 f"per high-level iteration")
    balanced = " ".join(f"{report.balanced_port_load.get(p, 0.0):5.2f}"
                        for p in shown_ports)
    lines.append(f"{balanced} | {'':5} {'':5} | "
                 f"balanced port load (optimal µ-op schedule, per block)")
    lines.append("")
    lines.append(f"TP  (lower bound): {report.tp_per_it:6.2f} cy/it   "
                 f"bottleneck port {report.bottleneck_port}  (uniform split)")
    lines.append(f"TP  (balanced)   : {report.tp_balanced_per_it:6.2f} cy/it   "
                 f"bottleneck port {report.balanced_bottleneck}  "
                 f"(min-max optimal assignment)")
    lines.append(f"LCD (expected)  : {report.lcd_per_it:6.2f} cy/it   "
                 f"{len(report.lcd_chains)} cyclic chain(s) found")
    lines.append(f"CP  (upper bound): {report.cp_per_it:6.2f} cy/it")
    if report.sim_block is not None:
        conv = (f"steady after {report.sim_copies} copies"
                if report.sim_converged
                else f"unconverged at {report.sim_copies} copies")
        clamp = (f", clamped to {report.sim_clamped.upper()}"
                 if report.sim_clamped else "")
        limiter = f", {report.sim_limiter}-limited" if report.sim_limiter else ""
        lines.append(f"sim (window OoO) : {report.sim_per_it:6.2f} cy/it   "
                     f"point prediction ({conv}{limiter}{clamp})")
    if report.degraded:
        stages = ",".join(report.stages_completed) or "(parse only)"
        lines.append("")
        lines.append(f"DEGRADED answer: rung={report.degradation}  "
                     f"stages completed: {stages} — numbers above cover "
                     f"only those stages (the rest read 0)")
    if report.findings is not None:
        lines.append("")
        if report.findings:
            lines.append(f"Diagnostics ({len(report.findings)} finding(s)):")
            for f in report.findings:
                anchor = (f"  [lines {','.join(map(str, f.lines))}]"
                          if f.lines else "")
                lines.append(f"  [{f.severity}] {f.code}: {f.message}{anchor}")
        else:
            lines.append("Diagnostics: no findings")
    return "\n".join(lines)


def _text_hlo(report) -> str:
    lines: List[str] = []
    lines.append(f"OSACA analysis  module={report.kernel_name}  "
                 f"arch={report.arch}  (HLO)")
    lines.append("engine pressure (roofline terms):")
    for port in report.ports:
        lines.append(f"  {port:>4}: {report.port_pressure.get(port, 0.0) * 1e3:9.4f} ms")
    lines.append(f"critical path ({len(report.rows)} ops):")
    for row in sorted(report.rows, key=lambda r: -r.latency)[:8]:
        lcd_mark = " LCD" if row.on_lcd else "    "
        lines.append(f"  {row.latency * 1e3:9.4f} ms{lcd_mark}  "
                     f"{row.mnemonic:<22} {row.asm}")
    lines.append("")
    lines.append(f"TP  (roofline bound): {report.tp_block * 1e3:9.4f} ms/step  "
                 f"bottleneck engine {report.bottleneck_port}")
    lines.append(f"LCD (expected)     : {report.lcd_block * 1e3:9.4f} ms/step  "
                 f"{len(report.lcd_chains)} carried chain(s) found")
    lines.append(f"CP  (upper bound)  : {report.cp_block * 1e3:9.4f} ms/step")
    return "\n".join(lines)


def render_text(report) -> str:
    return _text_hlo(report) if report.kind == "hlo" else _text_asm(report)


# ---------------------------------------------------------------------------
# json / markdown
# ---------------------------------------------------------------------------


def render_json(report) -> str:
    return report.to_json(indent=2, sort_keys=True)


def render_markdown(report) -> str:
    unit = "ms" if report.kind == "hlo" else "cy"
    scale = 1e3 if report.kind == "hlo" else 1.0
    shown_ports = _shown_ports(report)
    lines: List[str] = []
    lines.append(f"### OSACA analysis — `{report.kernel_name}` on "
                 f"`{report.arch}` (unroll {report.unroll}x)")
    lines.append("")
    lines.append("| # | " + " | ".join(shown_ports) +
                 " | LCD | CP | assembly |")
    lines.append("|---|" + "---|" * (len(shown_ports) + 3))
    for row in report.rows:
        cells = " | ".join(
            f"{row.port_pressure.get(p, 0.0):.2f}"
            if row.port_pressure.get(p, 0.0) else ""
            for p in shown_ports
        )
        lcd = f"{row.latency * scale:.1f}" if row.on_lcd else ""
        cp = f"{row.latency * scale:.1f}" if row.on_critical_path else ""
        lines.append(f"| {row.index} | {cells} | {lcd} | {cp} | "
                     f"`{row.asm}` |")
    lines.append("")
    bracket = report.prediction_bracket()
    lines.append(f"- **TP** (lower bound): "
                 f"{bracket['lower_bound_tp'] * scale:.2f} {unit}/it — "
                 f"bottleneck `{report.bottleneck_port}`")
    if report.kind != "hlo":
        util = ", ".join(
            f"`{p}`={report.balanced_port_load.get(p, 0.0):.2f}"
            for p in shown_ports)
        lines.append(f"- **TP** (balanced): "
                     f"{report.tp_balanced_per_it * scale:.2f} {unit}/it — "
                     f"optimal µ-op→port assignment, bottleneck "
                     f"`{report.balanced_bottleneck}`; per-block port load: "
                     f"{util}")
    lines.append(f"- **LCD** (expected): "
                 f"{bracket['expected_lcd'] * scale:.2f} {unit}/it — "
                 f"{len(report.lcd_chains)} cyclic chain(s)")
    lines.append(f"- **CP** (upper bound): "
                 f"{bracket['upper_bound_cp'] * scale:.2f} {unit}/it")
    if report.sim_block is not None:
        detail = ("converged" if report.sim_converged else "unconverged") + \
            (f", {report.sim_limiter}-limited" if report.sim_limiter else "") + \
            (f", clamped to {report.sim_clamped.upper()}"
             if report.sim_clamped else "")
        lines.append(f"- **sim** (point prediction): "
                     f"{report.sim_per_it * scale:.2f} {unit}/it — "
                     f"window-limited OoO simulation ({detail})")
    if report.degraded:
        stages = ", ".join(report.stages_completed) or "parse only"
        lines.append(f"- **DEGRADED** — rung `{report.degradation}`; "
                     f"stages completed: {stages}")
    if report.findings is not None:
        lines.append("")
        lines.append(f"#### Diagnostics ({len(report.findings)} finding(s))")
        if report.findings:
            for f in report.findings:
                anchor = (f" _(lines {', '.join(map(str, f.lines))})_"
                          if f.lines else "")
                lines.append(f"- **{f.severity}** `{f.code}` — "
                             f"{f.message}{anchor}")
        else:
            lines.append("- no findings")
    return "\n".join(lines)


register_renderer("text", render_text)
register_renderer("json", render_json)
register_renderer("markdown", render_markdown)
