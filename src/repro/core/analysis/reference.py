"""Retained pure-Python reference engine (the seed implementation).

This module preserves the original per-source analysis algorithms exactly as
they shipped in the seed tree, so the batched array engine in
:mod:`repro.core.analysis.lcd` / :mod:`repro.core.analysis.critical_path` can
be differential-tested against them (``tests/test_engine_equivalence.py``):

* :func:`reference_critical_path` — one node-weighted longest-path DP over a
  1-copy DAG (``DependencyDAG.longest_paths``).
* :func:`reference_loop_carried_dependencies` — one full longest-path DP *per
  body instruction* over a 2-copy DAG: the O(n·(V+E)) loop the batched
  single-sweep engine replaces.

Do not optimize this module; its value is being the slow, obviously-correct
oracle.
"""

from __future__ import annotations

from typing import Dict

from repro.core.analysis.critical_path import CriticalPathResult
from repro.core.analysis.dag import build_dag
from repro.core.analysis.lcd import LCDChain, LCDResult
from repro.core.isa.instruction import Kernel
from repro.core.machine.model import MachineModel


def reference_critical_path(kernel: Kernel, model: MachineModel) -> CriticalPathResult:
    dag = build_dag(kernel, model, copies=1)
    if not dag.nodes:
        return CriticalPathResult(length=0.0, path=(), on_path=set())
    dist, parent = dag.longest_paths()
    end = max(range(len(dag.nodes)), key=lambda v: dist[v])
    path_ids = dag.path_to(end, parent)
    path = tuple(dag.nodes[v] for v in path_ids)
    return CriticalPathResult(
        length=dist[end],
        path=path,
        on_path={n.instr_index for n in path if n.kind == "instr"},
    )


def reference_loop_carried_dependencies(
    kernel: Kernel, model: MachineModel
) -> LCDResult:
    dag = build_dag(kernel, model, copies=2, writeback_chains_data=False)
    n_body = len(kernel)
    seen: Dict[frozenset, LCDChain] = {}

    for idx in range(n_body):
        src = dag.instr_node.get((idx, 0))
        dst = dag.instr_node.get((idx, 1))
        if src is None or dst is None:
            continue
        dist, parent = dag.longest_paths(sources=[src])
        if dist[dst] == float("-inf"):
            continue
        path_ids = dag.path_to(dst, parent)
        if not path_ids or path_ids[0] != src:
            continue
        # One period: exclude the duplicate endpoint's latency.
        period = dist[dst] - dag.nodes[dst].latency
        members = tuple(
            dag.nodes[v].instr_index for v in path_ids[:-1]
            if dag.nodes[v].kind == "instr"
        )
        key = frozenset(members)
        if key not in seen or seen[key].length < period:
            seen[key] = LCDChain(length=period, instr_indices=members, carried_by=idx)

    chains = tuple(sorted(seen.values(), key=lambda c: -c.length))
    if chains:
        return LCDResult(chains=chains, longest=chains[0].length,
                         on_longest=set(chains[0].instr_indices))
    return LCDResult(chains=(), longest=0.0, on_longest=set())
