"""Register-dependency DAG construction (paper §II-C rules 1-4).

1. A vertex per instruction form in the marked code.
2. From each destination register, edges to every later instruction reading it
   until the register is redefined (or a dependency break, e.g. zero idiom).
3. Path weights are the source instruction latencies; OSACA's reported CP
   totals additionally include the terminal vertex latency, so we equivalently
   treat the DAG as *node-weighted* (longest path = sum of node latencies).
4. A source memory reference whose address has a register dependency gets an
   intermediate load vertex carrying the load latency (memory-operand
   splitting); pure load instructions are themselves the load vertex.

AArch64 writeback forms (``str d5, [x14], 8``) write their base register, so
they appear as defs like any other — this is how the store→address→load chain
of the paper's Table II ends up on the critical path.  For the *LCD* analysis
the writeback is modeled as the separate address-update µ-op it really is
(depending only on the address registers, not the store data): this matches
both the hardware behaviour and OSACA's published Table II, whose CP column
includes the str→ldr segment while its LCD chain carries the pure FP
dependency (``writeback_chains_data`` selects between the two).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.isa.instruction import Kernel
from repro.core.machine.model import InstructionCost, MachineModel


@dataclass
class Node:
    nid: int
    kind: str  # "instr" | "load"
    instr_index: int  # index within the *original* kernel body
    copy: int  # which duplicated copy of the body (0 for plain CP analysis)
    latency: float
    cost: Optional[InstructionCost] = None

    @property
    def line_number(self) -> int:
        return self.cost.form.line_number if self.cost is not None else -1


@dataclass
class DependencyDAG:
    nodes: List[Node]
    succs: List[List[int]]
    preds: List[List[int]]
    # instruction node id for (instr_index, copy)
    instr_node: Dict[Tuple[int, int], int] = field(default_factory=dict)

    def add_node(self, node: Node) -> int:
        node.nid = len(self.nodes)
        self.nodes.append(node)
        self.succs.append([])
        self.preds.append([])
        return node.nid

    def add_edge(self, src: int, dst: int) -> None:
        if src == dst:
            return
        if dst not in self.succs[src]:
            self.succs[src].append(dst)
            self.preds[dst].append(src)

    def longest_paths(self, sources: Optional[List[int]] = None) -> Tuple[List[float], List[int]]:
        """Node-weighted longest path DP over the (already topological) ids.

        Returns ``(dist, parent)`` where ``dist[v]`` is the maximum node-
        latency sum over paths ending at ``v``.  If ``sources`` is given, only
        paths starting in ``sources`` count (others get ``-inf``).
        """
        n = len(self.nodes)
        neg = float("-inf")
        dist = [neg] * n
        parent = [-1] * n
        allowed_start = set(sources) if sources is not None else None
        for v in range(n):
            best_pred = -1
            best = neg
            for u in self.preds[v]:
                if dist[u] > best:
                    best = dist[u]
                    best_pred = u
            if best == neg:
                if allowed_start is None or v in allowed_start:
                    dist[v] = self.nodes[v].latency
            else:
                dist[v] = best + self.nodes[v].latency
                parent[v] = best_pred
            if allowed_start is not None and v in allowed_start and dist[v] < self.nodes[v].latency:
                dist[v] = self.nodes[v].latency
                parent[v] = -1
        return dist, parent

    def path_to(self, v: int, parent: List[int]) -> List[int]:
        path = []
        while v != -1:
            path.append(v)
            v = parent[v]
        path.reverse()
        return path


# x86 mnemonic families that write / read the status flags (hidden deps,
# paper §IV-B "future work"); AArch64 writes flags only via the -s forms.
_X86_FLAG_WRITERS = ("add", "sub", "inc", "dec", "neg", "and", "or", "xor",
                     "test", "cmp", "shl", "shr", "sar", "sal", "bt", "adc",
                     "sbb")
_X86_FLAG_READERS = ("j", "set", "cmov", "adc", "sbb")
_A64_FLAG_READERS = ("b.", "bne", "beq", "bgt", "blt", "bge", "ble", "bhi",
                     "bls", "csel", "csinc", "cset", "ccmp", "adc", "sbc")


def _writes_flags(form, isa: str) -> bool:
    m = form.mnemonic
    if isa == "x86":
        return any(m.startswith(p) for p in _X86_FLAG_WRITERS) and not m.startswith("jmp")
    return m in ("cmp", "cmn", "tst", "ccmp") or m.endswith("s") and m in (
        "adds", "subs", "ands", "bics")


def _reads_flags(form, isa: str) -> bool:
    m = form.mnemonic
    if isa == "x86":
        return any(m.startswith(p) for p in _X86_FLAG_READERS) and m != "jmp"
    return any(m.startswith(p) for p in _A64_FLAG_READERS)


def build_dag(
    kernel: Kernel,
    model: MachineModel,
    copies: int = 1,
    writeback_chains_data: bool = True,
    model_flags: bool = False,
    model_store_forwarding: bool = False,
) -> DependencyDAG:
    """Build the dependency DAG over ``copies`` back-to-back body copies.

    ``writeback_chains_data=False`` splits pre-/post-index writeback into its
    own address-update µ-op node (latency 1, integer ALU) so store data does
    not chain into later address uses — used by the LCD analysis.

    Beyond-paper extensions (the paper's §IV-B future-work list), both off by
    default to preserve the published semantics:

    * ``model_flags`` — hidden status-flag dependencies: flag-writers define
      a pseudo-register ``%flags`` consumed by conditional ops.
    * ``model_store_forwarding`` — load-after-store: a load whose memory
      reference is syntactically identical to an earlier store's depends on
      it (store-forward latency = the store's DB latency).
    """
    costs = model.resolve_kernel(kernel)
    dag = DependencyDAG(nodes=[], succs=[], preds=[])
    last_def: Dict[str, int] = {}
    last_store: Dict[tuple, int] = {}  # memory-ref signature -> store node

    def _mem_key(mem, copy_tag=None):
        return (mem.base.name if mem.base else None,
                mem.index.name if mem.index else None,
                mem.scale, mem.offset)

    for copy in range(copies):
        for idx, cost in enumerate(costs):
            form = cost.form
            addr_regs = {
                r.name
                for mem in (*form.loads, *form.stores)
                for r in mem.address_registers
            }
            writeback_regs = {
                mem.base.name
                for mem in (*form.loads, *form.stores)
                if (mem.post_index or mem.pre_index) and mem.base is not None
            }
            data_sources = [s for s in form.source_registers if s not in addr_regs]

            load_node_id = None
            if cost.load is not None:
                # Split-off load µ-op: address regs feed the load vertex.
                load_node_id = dag.add_node(
                    Node(nid=-1, kind="load", instr_index=idx, copy=copy,
                         latency=cost.load.latency, cost=cost)
                )
                for r in addr_regs:
                    if r in last_def:
                        dag.add_edge(last_def[r], load_node_id)

            nid = dag.add_node(
                Node(nid=-1, kind="instr", instr_index=idx, copy=copy,
                     latency=cost.entry.latency, cost=cost)
            )
            dag.instr_node[(idx, copy)] = nid
            if load_node_id is not None:
                dag.add_edge(load_node_id, nid)
            else:
                # Pure loads/stores: address regs feed the instruction itself.
                for r in addr_regs:
                    if r in last_def:
                        dag.add_edge(last_def[r], nid)
            if not form.is_dep_breaking:
                for r in data_sources:
                    if r in last_def:
                        dag.add_edge(last_def[r], nid)

            if model_flags:
                if _reads_flags(form, kernel.isa) and "%flags" in last_def:
                    dag.add_edge(last_def["%flags"], nid)
                if _writes_flags(form, kernel.isa):
                    last_def["%flags"] = nid

            if model_store_forwarding:
                read_node = load_node_id if load_node_id is not None else nid
                for mem in form.loads:
                    key = _mem_key(mem)
                    if key in last_store:
                        dag.add_edge(last_store[key], read_node)
                for mem in form.stores:
                    last_store[_mem_key(mem)] = nid

            wb_node_id = None
            if writeback_regs and not writeback_chains_data:
                # Separate address-update µ-op: depends only on address regs.
                wb_node_id = dag.add_node(
                    Node(nid=-1, kind="instr", instr_index=idx, copy=copy,
                         latency=1.0, cost=cost)
                )
                for r in addr_regs:
                    if r in last_def:
                        dag.add_edge(last_def[r], wb_node_id)

            for r in form.dest_registers:
                if r in writeback_regs and wb_node_id is not None:
                    last_def[r] = wb_node_id
                else:
                    last_def[r] = nid
    return dag
