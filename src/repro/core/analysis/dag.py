"""Register-dependency DAG construction (paper §II-C rules 1-4).

1. A vertex per instruction form in the marked code.
2. From each destination register, edges to every later instruction reading it
   until the register is redefined (or a dependency break, e.g. zero idiom).
3. Path weights are the source instruction latencies; OSACA's reported CP
   totals additionally include the terminal vertex latency, so we equivalently
   treat the DAG as *node-weighted* (longest path = sum of node latencies).
4. A source memory reference whose address has a register dependency gets an
   intermediate load vertex carrying the load latency (memory-operand
   splitting); pure load instructions are themselves the load vertex.

AArch64 writeback forms (``str d5, [x14], 8``) write their base register, so
they appear as defs like any other — this is how the store→address→load chain
of the paper's Table II ends up on the critical path.  For the *LCD* analysis
the writeback is modeled as the separate address-update µ-op it really is
(depending only on the address registers, not the store data): this matches
both the hardware behaviour and OSACA's published Table II, whose CP column
includes the str→ldr segment while its LCD chain carries the pure FP
dependency (``writeback_chains_data`` selects between the two).

Array engine notes
------------------
Node ids are assigned in program order, and every dependency edge points
forward (a def strictly precedes its uses), so the id order *is* a topological
order.  The longest-path analyses therefore never need an explicit toposort:
they run a single forward sweep over ids, reducing over each node's
predecessor list.  :meth:`DependencyDAG.pred_csr` exports the predecessor
lists as a NumPy CSR pair ``(ptr, idx)`` (plus a contiguous per-node latency
vector via :meth:`DependencyDAG.latency_vector`), which is what
:func:`repro.core.analysis.sweep.batched_longest_paths` consumes to compute
longest paths from *all* LCD source candidates in one vectorized sweep — a
(sources × nodes) distance matrix updated with a ``max``-over-predecessors
reduction per node, O(V + S·E) vectorized work instead of S independent
Python DPs.

Edge insertion is O(1): a parallel set of ``(src, dst)`` pairs backs the
duplicate check instead of a linear scan of the successor list.

``build_dag(..., dual_writeback=True)`` builds *both* writeback models over a
single node list in one pass: the default ``succs``/``preds`` adjacency is the
LCD view (writeback split into its own address-update µ-op) while ``cp_preds``
holds the CP view (store data chains through the writeback def).  That is what
lets :func:`repro.core.analysis.analyze.analyze_kernel` share one
``resolve_kernel`` and one DAG build across the TP/CP/LCD analyses.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.analysis.sweep import pred_csr_from_lists
from repro.core.isa.instruction import Kernel
from repro.core.machine.model import InstructionCost, MachineModel


@dataclass
class Node:
    nid: int
    kind: str  # "instr" | "load"
    instr_index: int  # index within the *original* kernel body
    copy: int  # which duplicated copy of the body (0 for plain CP analysis)
    latency: float
    cost: Optional[InstructionCost] = None
    # Writeback address-update µ-op marker.  These nodes only exist for the
    # LCD view; the CP end-node scan skips them.  (They keep kind="instr" so
    # LCD chain membership is unchanged from the seed engine.)
    is_wb: bool = False

    @property
    def line_number(self) -> int:
        return self.cost.form.line_number if self.cost is not None else -1


@dataclass
class DependencyDAG:
    nodes: List[Node]
    succs: List[List[int]]
    preds: List[List[int]]
    # instruction node id for (instr_index, copy)
    instr_node: Dict[Tuple[int, int], int] = field(default_factory=dict)
    # CP-view predecessor lists (dual-writeback builds only); ``None`` means
    # the default adjacency doubles as the CP view.
    cp_preds: Optional[List[List[int]]] = None
    # O(1) duplicate-edge checks (parallel to succs/preds and cp_preds).
    _edges: Set[Tuple[int, int]] = field(default_factory=set, repr=False)
    _cp_edges: Set[Tuple[int, int]] = field(default_factory=set, repr=False)

    def add_node(self, node: Node) -> int:
        node.nid = len(self.nodes)
        self.nodes.append(node)
        self.succs.append([])
        self.preds.append([])
        if self.cp_preds is not None:
            self.cp_preds.append([])
        return node.nid

    def add_edge(self, src: int, dst: int) -> None:
        if src == dst:
            return
        if (src, dst) not in self._edges:
            self._edges.add((src, dst))
            self.succs[src].append(dst)
            self.preds[dst].append(src)

    def add_cp_edge(self, src: int, dst: int) -> None:
        """Add an edge to the CP view of a dual-writeback build."""
        if src == dst or self.cp_preds is None:
            return
        if (src, dst) not in self._cp_edges:
            self._cp_edges.add((src, dst))
            self.cp_preds[dst].append(src)

    # -- array export ------------------------------------------------------

    def pred_csr(self, preds: Optional[List[List[int]]] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Predecessor lists as a CSR pair ``(ptr, idx)``.

        ``idx[ptr[v]:ptr[v+1]]`` are the predecessors of ``v`` in insertion
        order (which the sweeps rely on for seed-identical tie-breaking).
        """
        return pred_csr_from_lists(self.preds if preds is None else preds)

    def latency_vector(self) -> np.ndarray:
        return np.array([n.latency for n in self.nodes], dtype=np.float64)

    # -- reference longest path (kept for the oracle implementation) -------

    def longest_paths(self, sources: Optional[List[int]] = None) -> Tuple[List[float], List[int]]:
        """Node-weighted longest path DP over the (already topological) ids.

        Returns ``(dist, parent)`` where ``dist[v]`` is the maximum node-
        latency sum over paths ending at ``v``.  If ``sources`` is given, only
        paths starting in ``sources`` count (others get ``-inf``).
        """
        n = len(self.nodes)
        neg = float("-inf")
        dist = [neg] * n
        parent = [-1] * n
        allowed_start = set(sources) if sources is not None else None
        for v in range(n):
            best_pred = -1
            best = neg
            for u in self.preds[v]:
                if dist[u] > best:
                    best = dist[u]
                    best_pred = u
            if best == neg:
                if allowed_start is None or v in allowed_start:
                    dist[v] = self.nodes[v].latency
            else:
                dist[v] = best + self.nodes[v].latency
                parent[v] = best_pred
            if allowed_start is not None and v in allowed_start and dist[v] < self.nodes[v].latency:
                dist[v] = self.nodes[v].latency
                parent[v] = -1
        return dist, parent

    def path_to(self, v: int, parent: List[int]) -> List[int]:
        path = []
        while v != -1:
            path.append(v)
            v = parent[v]
        path.reverse()
        return path


# x86 mnemonic families that write / read the status flags (hidden deps,
# paper §IV-B "future work"); AArch64 writes flags only via the -s forms.
_X86_FLAG_WRITERS = ("add", "sub", "inc", "dec", "neg", "and", "or", "xor",
                     "test", "cmp", "shl", "shr", "sar", "sal", "bt", "adc",
                     "sbb")
_X86_FLAG_READERS = ("j", "set", "cmov", "adc", "sbb")
_A64_FLAG_READERS = ("b.", "bne", "beq", "bgt", "blt", "bge", "ble", "bhi",
                     "bls", "csel", "csinc", "cset", "ccmp", "adc", "sbc")


def _writes_flags(form, isa: str) -> bool:
    m = form.mnemonic
    if isa == "x86":
        return any(m.startswith(p) for p in _X86_FLAG_WRITERS) and not m.startswith("jmp")
    return m in ("cmp", "cmn", "tst", "ccmp") or m.endswith("s") and m in (
        "adds", "subs", "ands", "bics")


def _reads_flags(form, isa: str) -> bool:
    m = form.mnemonic
    if isa == "x86":
        return any(m.startswith(p) for p in _X86_FLAG_READERS) and m != "jmp"
    return any(m.startswith(p) for p in _A64_FLAG_READERS)


def build_dag(
    kernel: Kernel,
    model: MachineModel,
    copies: int = 1,
    writeback_chains_data: bool = True,
    model_flags: bool = False,
    model_store_forwarding: bool = False,
    costs: Optional[Tuple[InstructionCost, ...]] = None,
    dual_writeback: bool = False,
) -> DependencyDAG:
    """Build the dependency DAG over ``copies`` back-to-back body copies.

    ``writeback_chains_data=False`` splits pre-/post-index writeback into its
    own address-update µ-op node (latency 1, integer ALU) so store data does
    not chain into later address uses — used by the LCD analysis.

    ``dual_writeback=True`` builds both writeback models at once over one node
    list: ``succs``/``preds`` carry the split-µ-op (LCD) view and ``cp_preds``
    the data-chained (CP) view.  ``writeback_chains_data`` is ignored then.

    ``costs`` reuses an already-resolved kernel (``model.resolve_kernel``)
    instead of resolving again.

    Beyond-paper extensions (the paper's §IV-B future-work list), both off by
    default to preserve the published semantics:

    * ``model_flags`` — hidden status-flag dependencies: flag-writers define
      a pseudo-register ``%flags`` consumed by conditional ops.
    * ``model_store_forwarding`` — load-after-store: a load whose memory
      reference is syntactically identical to an earlier store's depends on
      it (store-forward latency = the store's DB latency).
    """
    if costs is None:
        costs = model.resolve_kernel(kernel)
    dag = DependencyDAG(nodes=[], succs=[], preds=[],
                        cp_preds=[] if dual_writeback else None)
    split_writeback = dual_writeback or not writeback_chains_data
    # Def maps: reg -> node id.  In dual mode the two views may disagree on
    # who defines a writeback base register (the µ-op vs. the store itself).
    last_def: Dict[str, int] = {}
    cp_last_def: Dict[str, int] = last_def if not dual_writeback else {}
    last_store: Dict[tuple, int] = {}  # memory-ref signature -> store node

    def _mem_key(mem):
        return (mem.base.name if mem.base else None,
                mem.index.name if mem.index else None,
                mem.scale, mem.offset)

    def _dep_edge(reg: str, dst: int) -> None:
        """Edge from the latest def of ``reg`` to ``dst``, in both views."""
        src = last_def.get(reg)
        if src is not None:
            dag.add_edge(src, dst)
        if dual_writeback:
            cp_src = cp_last_def.get(reg)
            if cp_src is not None:
                dag.add_cp_edge(cp_src, dst)

    def _shared_edge(src: int, dst: int) -> None:
        """Structural edge present identically in both views."""
        dag.add_edge(src, dst)
        dag.add_cp_edge(src, dst)

    for copy in range(copies):
        for idx, cost in enumerate(costs):
            form = cost.form
            addr_regs = {
                r.name
                for mem in (*form.loads, *form.stores)
                for r in mem.address_registers
            }
            writeback_regs = {
                mem.base.name
                for mem in (*form.loads, *form.stores)
                if (mem.post_index or mem.pre_index) and mem.base is not None
            }
            data_sources = [s for s in form.source_registers if s not in addr_regs]

            load_node_id = None
            if cost.load is not None:
                # Split-off load µ-op: address regs feed the load vertex.
                load_node_id = dag.add_node(
                    Node(nid=-1, kind="load", instr_index=idx, copy=copy,
                         latency=cost.load.latency, cost=cost)
                )
                for r in addr_regs:
                    _dep_edge(r, load_node_id)

            nid = dag.add_node(
                Node(nid=-1, kind="instr", instr_index=idx, copy=copy,
                     latency=cost.entry.latency, cost=cost)
            )
            dag.instr_node[(idx, copy)] = nid
            if load_node_id is not None:
                _shared_edge(load_node_id, nid)
            else:
                # Pure loads/stores: address regs feed the instruction itself.
                for r in addr_regs:
                    _dep_edge(r, nid)
            if not form.is_dep_breaking:
                for r in data_sources:
                    _dep_edge(r, nid)

            if model_flags:
                if _reads_flags(form, kernel.isa):
                    _dep_edge("%flags", nid)
                if _writes_flags(form, kernel.isa):
                    last_def["%flags"] = nid
                    if dual_writeback:
                        cp_last_def["%flags"] = nid

            if model_store_forwarding:
                read_node = load_node_id if load_node_id is not None else nid
                for mem in form.loads:
                    key = _mem_key(mem)
                    if key in last_store:
                        _shared_edge(last_store[key], read_node)
                for mem in form.stores:
                    last_store[_mem_key(mem)] = nid

            wb_node_id = None
            if writeback_regs and split_writeback:
                # Separate address-update µ-op: depends only on address regs.
                # In dual mode it exists only in the LCD view (no CP edges),
                # so the CP sweep never sees it.
                wb_node_id = dag.add_node(
                    Node(nid=-1, kind="instr", instr_index=idx, copy=copy,
                         latency=1.0, cost=cost, is_wb=True)
                )
                for r in addr_regs:
                    src = last_def.get(r)
                    if src is not None:
                        dag.add_edge(src, wb_node_id)

            for r in form.dest_registers:
                if r in writeback_regs and wb_node_id is not None:
                    last_def[r] = wb_node_id
                else:
                    last_def[r] = nid
                if dual_writeback:
                    cp_last_def[r] = nid
    return dag
