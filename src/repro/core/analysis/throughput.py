"""Block throughput analysis (paper §II-B).

Every instruction's port pressure (after memory-operand splitting and macro
fusion) is accumulated per port; the block reciprocal throughput is the
maximum accumulated pressure over all ports.  This assumes perfect
out-of-order scheduling and no dependencies — a *lower bound* on the runtime
of one loop iteration.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.core.isa.instruction import Kernel
from repro.core.machine.model import InstructionCost, MachineModel


@dataclass
class ThroughputResult:
    port_pressure: Dict[str, float]  # accumulated cycles per port (per block)
    per_instruction: Tuple[Tuple[InstructionCost, Dict[str, float]], ...]
    block_throughput: float  # cycles per assembly-block iteration
    bottleneck_port: str

    def per_iteration(self, unroll: int) -> float:
        return self.block_throughput / unroll


def throughput_analysis(kernel: Kernel, model: MachineModel,
                        costs=None) -> ThroughputResult:
    if costs is None:
        costs = model.resolve_kernel(kernel)
    return throughput_from_costs(costs, model)


def throughput_from_costs(costs, model: MachineModel) -> ThroughputResult:
    """Accumulate port pressure from already-resolved instruction costs."""
    totals: Dict[str, float] = {p: 0.0 for p in model.ports}
    per_instruction = []
    for cost in costs:
        pressure = cost.total_pressure
        for port, cy in pressure.items():
            totals[port] = totals.get(port, 0.0) + cy
        per_instruction.append((cost, pressure))
    bottleneck = max(totals, key=lambda p: totals[p]) if totals else ""
    return ThroughputResult(
        port_pressure=totals,
        per_instruction=tuple(per_instruction),
        block_throughput=totals.get(bottleneck, 0.0),
        bottleneck_port=bottleneck,
    )
