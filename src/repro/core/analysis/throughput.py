"""Block throughput analysis (paper §II-B) — two bounds per kernel.

*Optimistic* (the paper's model): every instruction's port pressure (after
memory-operand splitting and macro fusion) is accumulated per port with the
fixed ``t/n`` uniform split; the block reciprocal throughput is the maximum
accumulated pressure over all ports.  Kept bit-identical to the published
Table I/II numbers.

*Balanced* (the headline bound): the same µ-ops assigned kernel-globally by
the min-max scheduler (:mod:`repro.core.analysis.scheduler`) — the optimal
fractional µ-op→port assignment, which is what a perfect out-of-order
scheduler actually achieves.  ``balanced <= optimistic`` always; they are
equal when every DB entry pins its µ-ops to explicit ports.

Both assume perfect scheduling and no dependencies — *lower bounds* on the
runtime of one loop iteration.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

from repro.core.analysis.scheduler import balance_from_costs
from repro.core.isa.instruction import Kernel
from repro.core.machine.model import InstructionCost, MachineModel


@dataclass
class ThroughputResult:
    port_pressure: Dict[str, float]  # accumulated cycles per port (per block)
    per_instruction: Tuple[Tuple[InstructionCost, Dict[str, float]], ...]
    block_throughput: float  # optimistic bound, cycles per block iteration
    bottleneck_port: str
    # Min-max optimal µ-op→port assignment (kernel-global water filling).
    balanced_throughput: float = 0.0  # balanced bound, cycles per block
    balanced_port_load: Dict[str, float] = field(default_factory=dict)
    balanced_bottleneck: str = ""

    def per_iteration(self, unroll: int) -> float:
        return self.block_throughput / unroll

    def balanced_per_iteration(self, unroll: int) -> float:
        return self.balanced_throughput / unroll


def throughput_analysis(kernel: Kernel, model: MachineModel,
                        costs=None) -> ThroughputResult:
    if costs is None:
        costs = model.resolve_kernel(kernel)
    return throughput_from_costs(costs, model)


def throughput_from_costs(costs, model: MachineModel,
                          balanced: bool = True) -> ThroughputResult:
    """Accumulate port pressure from already-resolved instruction costs.

    ``balanced=False`` skips the min-max scheduler and mirrors the optimistic
    numbers into the balanced fields — the pure full-throughput model, used
    by the serving path's ``tp_only`` degradation rung where the point is to
    still answer after the expensive stages were cut.
    """
    totals: Dict[str, float] = {p: 0.0 for p in model.ports}
    per_instruction = []
    for cost in costs:
        pressure = cost.total_pressure
        for port, cy in pressure.items():
            totals[port] = totals.get(port, 0.0) + cy
        per_instruction.append((cost, pressure))
    bottleneck = max(totals, key=lambda p: totals[p]) if totals else ""
    if balanced:
        schedule = balance_from_costs(costs, model.ports)
        bal_bound = schedule.bound
        bal_load = schedule.port_load
        bal_port = schedule.bottleneck_port
    else:
        bal_bound = totals.get(bottleneck, 0.0)
        bal_load = dict(totals)
        bal_port = bottleneck
    return ThroughputResult(
        port_pressure=totals,
        per_instruction=tuple(per_instruction),
        block_throughput=totals.get(bottleneck, 0.0),
        bottleneck_port=bottleneck,
        balanced_throughput=bal_bound,
        balanced_port_load=bal_load,
        balanced_bottleneck=bal_port,
    )
