"""Combined OSACA analysis: TP + CP + LCD with a Table-II-style report."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core.analysis.critical_path import CriticalPathResult, critical_path
from repro.core.analysis.lcd import LCDResult, loop_carried_dependencies
from repro.core.analysis.throughput import ThroughputResult, throughput_analysis
from repro.core.isa.instruction import Kernel
from repro.core.machine.model import MachineModel


@dataclass
class Analysis:
    kernel: Kernel
    model: MachineModel
    unroll: int
    tp: ThroughputResult
    cp: CriticalPathResult
    lcd: LCDResult

    # Per high-level (source) iteration numbers — the paper's Table I units.
    @property
    def tp_per_it(self) -> float:
        return self.tp.per_iteration(self.unroll)

    @property
    def cp_per_it(self) -> float:
        return self.cp.per_iteration(self.unroll)

    @property
    def lcd_per_it(self) -> float:
        return self.lcd.per_iteration(self.unroll)

    def prediction_bracket(self) -> Dict[str, float]:
        """[TP, CP] runtime bracket with the LCD as the expected value."""
        return {
            "lower_bound_tp": self.tp_per_it,
            "expected_lcd": self.lcd_per_it,
            "upper_bound_cp": self.cp_per_it,
        }

    def report(self) -> str:
        """Render a condensed Table-II-style report."""
        shown_ports = [p for p in self.model.ports
                       if self.tp.port_pressure.get(p, 0.0) > 0.0]
        head = " ".join(f"{p:>5}" for p in shown_ports)
        lines: List[str] = []
        lines.append(f"OSACA analysis  kernel={self.kernel.name}  "
                     f"arch={self.model.name}  unroll={self.unroll}x")
        lines.append(f"{head} | {'LCD':>5} {'CP':>5} | {'LN':>4} | assembly")
        lines.append("-" * (len(head) + 32))
        for idx, (cost, pressure) in enumerate(self.tp.per_instruction):
            cells = " ".join(
                f"{pressure.get(p, 0.0):5.2f}" if pressure.get(p, 0.0) else "     "
                for p in shown_ports
            )
            lat = cost.entry.latency
            lcd_mark = f"{lat:5.1f}" if idx in self.lcd.on_longest else "     "
            cp_mark = f"{lat:5.1f}" if idx in self.cp.on_path else "     "
            ln = cost.form.line_number
            lines.append(f"{cells} | {lcd_mark} {cp_mark} | {ln:>4} | "
                         f"{cost.form.raw.strip()}")
        lines.append("-" * (len(head) + 32))
        totals = " ".join(f"{self.tp.port_pressure.get(p, 0.0):5.2f}" for p in shown_ports)
        lines.append(f"{totals} | {self.lcd.longest:5.1f} {self.cp.length:5.1f} | "
                     f"(per {self.unroll}x-unrolled block)")
        per_it = " ".join(
            f"{self.tp.port_pressure.get(p, 0.0) / self.unroll:5.2f}" for p in shown_ports
        )
        lines.append(f"{per_it} | {self.lcd_per_it:5.1f} {self.cp_per_it:5.1f} | "
                     f"per high-level iteration")
        lines.append("")
        lines.append(f"TP  (lower bound): {self.tp_per_it:6.2f} cy/it   "
                     f"bottleneck port {self.tp.bottleneck_port}")
        lines.append(f"LCD (expected)  : {self.lcd_per_it:6.2f} cy/it   "
                     f"{len(self.lcd.chains)} cyclic chain(s) found")
        lines.append(f"CP  (upper bound): {self.cp_per_it:6.2f} cy/it")
        return "\n".join(lines)


def analyze_kernel(kernel: Kernel, model: MachineModel, unroll: int = 1) -> Analysis:
    return Analysis(
        kernel=kernel,
        model=model,
        unroll=unroll,
        tp=throughput_analysis(kernel, model),
        cp=critical_path(kernel, model),
        lcd=loop_carried_dependencies(kernel, model),
    )
