"""Combined OSACA analysis: TP + CP + LCD + sim with a Table-II-style report.

Single-sweep pipeline: one ``resolve_kernel`` and one dual-writeback 2-copy
DAG build are shared across all analyses — TP accumulates pressure from the
resolved costs, LCD runs the batched all-sources sweep over the DAG's
split-writeback view, CP reuses the same DAG's copy-0 data-chained view, and
the window-limited OoO simulator (:mod:`repro.core.sim`) replays the same
DAG as its replication template to close the [TP, CP] bracket with a point
prediction.

``predictors=`` selects a subset of ``("tp", "cp", "lcd", "sim")``: the DAG
is only built when a DAG-consuming predictor is requested, TP is always
computed (per-instruction rows need it), and ``sim`` implies ``cp`` (the
point prediction is clamped into the bracket).

``analyze_kernels`` is the batch entry point (one warm model cache across
kernels, process-level LRU keyed by kernel text + model name + unroll +
predictors) for serving paths that analyze many — often repeated — kernels
concurrently.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.analysis.critical_path import (CriticalPathResult,
                                               critical_path_from_dag)
from repro.core.analysis.dag import build_dag
from repro.core.analysis.diagnostics import Finding
from repro.core.analysis.diagnostics import diagnose as diagnose_analysis
from repro.core.analysis.lcd import LCDResult, lcd_from_dag
from repro.core.analysis.report import AnalysisReport
from repro.core.analysis.throughput import (ThroughputResult,
                                            throughput_from_costs)
from repro.core.isa.instruction import Kernel
from repro.core.machine.model import MachineModel
from repro.core.sim.engine import SimResult, simulate_from_dag


#: Pipeline stages in execution order; the degradation ladder cuts suffixes.
ANALYSIS_STAGES: Tuple[str, ...] = ("resolve", "tp", "dag", "cp", "lcd", "sim")

#: Degradation rungs, most complete first.  ``full`` is TP(both bounds) +
#: CP + LCD + the window-limited simulator; ``bracket`` drops the simulator
#: (the legacy [TP, CP] + LCD answer); ``tp_only`` is the optimistic
#: full-throughput model alone (no DAG, no scheduler); ``parse_only``
#: answers with parse-level facts only.
DEGRADATION_LADDER: Tuple[str, ...] = ("full", "bracket", "tp_only",
                                       "parse_only")

_RUNG_STAGES: Dict[str, Tuple[str, ...]] = {
    "full": ANALYSIS_STAGES,
    "bracket": ("resolve", "tp", "dag", "cp", "lcd"),
    "tp_only": ("resolve", "tp"),
    "parse_only": (),
}

#: Selectable predictors for ``analyze_kernel(..., predictors=...)``.
PREDICTORS: Tuple[str, ...] = ("tp", "cp", "lcd", "sim")


def normalize_predictors(predictors) -> Tuple[str, ...]:
    """Canonical predictor subset: validated, ordered, with implied members.

    ``None`` or an empty selection means *all* predictors.  ``tp`` is always
    included (the per-instruction rows and every rung need it) and ``sim``
    implies ``cp`` — the simulator's point prediction is clamped into the
    [TP, CP] bracket, so it needs the upper bound.
    """
    if predictors is None:
        return PREDICTORS
    requested = set(predictors)
    if not requested:
        return PREDICTORS
    unknown = requested - set(PREDICTORS)
    if unknown:
        raise ValueError(f"unknown predictors {sorted(unknown)}; "
                         f"known: {PREDICTORS}")
    requested.add("tp")
    if "sim" in requested:
        requested.add("cp")
    return tuple(p for p in PREDICTORS if p in requested)


@dataclass
class Analysis:
    kernel: Kernel
    model: MachineModel
    unroll: int
    # None below "full" on the degradation ladder: a tp_only analysis has no
    # cp/lcd, a parse_only analysis has none of the three.
    tp: Optional[ThroughputResult]
    cp: Optional[CriticalPathResult]
    lcd: Optional[LCDResult]
    # Window-limited OoO point prediction; ``None`` when not requested, when
    # the rung dropped it, or when the machine has no window parameters.
    sim: Optional[SimResult] = None
    # Structured bottleneck diagnostics (``diagnose=True``); ``None`` means
    # the pass did not run, ``()`` means it ran and found nothing.
    findings: Optional[Tuple[Finding, ...]] = None
    degradation: str = "full"  # ladder rung that produced this analysis
    stages_completed: Tuple[str, ...] = ANALYSIS_STAGES

    @property
    def degraded(self) -> bool:
        return self.degradation != "full"

    # Per high-level (source) iteration numbers — the paper's Table I units.
    # Degraded analyses report 0.0 for the numbers their rung did not
    # compute; check ``degraded`` / ``stages_completed`` to tell them apart.
    @property
    def tp_per_it(self) -> float:
        return self.tp.per_iteration(self.unroll) if self.tp else 0.0

    @property
    def tp_balanced_per_it(self) -> float:
        """Min-max optimal-assignment throughput bound (cy per iteration)."""
        return self.tp.balanced_per_iteration(self.unroll) if self.tp else 0.0

    @property
    def cp_per_it(self) -> float:
        return self.cp.per_iteration(self.unroll) if self.cp else 0.0

    @property
    def lcd_per_it(self) -> float:
        return self.lcd.per_iteration(self.unroll) if self.lcd else 0.0

    @property
    def sim_per_it(self) -> float:
        return self.sim.per_iteration(self.unroll) if self.sim else 0.0

    def prediction_bracket(self) -> Dict[str, float]:
        """[TP, CP] runtime bracket with the LCD as the expected value."""
        return {
            "lower_bound_tp": self.tp_per_it,
            "expected_lcd": self.lcd_per_it,
            "upper_bound_cp": self.cp_per_it,
        }

    def to_report(self) -> "AnalysisReport":
        """Snapshot into the serializable public-API report (memoized: on a
        serving path the same cached analysis is reported many times)."""
        report = self.__dict__.get("_report_memo")
        if report is None:
            report = AnalysisReport.from_analysis(self)
            self.__dict__["_report_memo"] = report
        return report

    def report(self) -> str:
        """Render a condensed Table-II-style report."""
        return self.to_report().render("text")


def analyze_kernel(kernel: Kernel, model: MachineModel, unroll: int = 1,
                   checkpoint: Optional[Callable[[str], None]] = None,
                   predictors=None, diagnose: bool = False) -> Analysis:
    """Full TP/CP/LCD/sim analysis: one cost resolution, one DAG build.

    ``checkpoint(stage)`` — when given — is called at every stage boundary
    (before the stage runs) and may raise to cancel the analysis: the serving
    path passes a deadline/fault-injection check so an expired request stops
    at the next boundary instead of finishing a report nobody is waiting for.
    The ``sim`` stage additionally re-checks once per simulated body copy, so
    a deadline can cancel *inside* the most expensive stage.

    ``predictors`` selects a subset of :data:`PREDICTORS`
    (see :func:`normalize_predictors`); the default runs everything.  The
    simulator is skipped — without error — on machines with no
    ``window`` parameters; ``stages_completed`` records what actually ran.

    ``diagnose=True`` runs the bottleneck-diagnostics pass
    (:mod:`repro.core.analysis.diagnostics`) over the finished analysis and
    attaches its findings.
    """
    preds = normalize_predictors(predictors)
    check = checkpoint or _no_checkpoint
    stages: List[str] = []
    check("resolve")
    costs = model.resolve_kernel(kernel)
    stages.append("resolve")
    check("tp")
    tp = throughput_from_costs(costs, model)
    stages.append("tp")
    cp = lcd = sim = None
    dag = None
    if any(p in preds for p in ("cp", "lcd", "sim")):
        check("dag")
        dag = build_dag(kernel, model, copies=2, dual_writeback=True,
                        costs=costs)
        stages.append("dag")
    if "cp" in preds:
        check("cp")
        cp = critical_path_from_dag(dag)
        stages.append("cp")
    if "lcd" in preds:
        check("lcd")
        lcd = lcd_from_dag(dag, len(kernel))
        stages.append("lcd")
    if "sim" in preds and model.window is not None:
        check("sim")
        sim = simulate_from_dag(dag, model,
                                tp_block=tp.balanced_throughput,
                                cp_block=cp.length if cp is not None else None,
                                cancel=(lambda: check("sim"))
                                if checkpoint is not None else None)
        stages.append("sim")
    analysis = Analysis(kernel=kernel, model=model, unroll=unroll,
                        tp=tp, cp=cp, lcd=lcd, sim=sim,
                        stages_completed=tuple(stages))
    if diagnose:
        analysis.findings = diagnose_analysis(analysis)
    return analysis


def _no_checkpoint(stage: str) -> None:
    return None


# -- degradation ladder ------------------------------------------------------


def analyze_kernel_bracket(kernel: Kernel, model: MachineModel,
                           unroll: int = 1,
                           checkpoint: Optional[Callable[[str], None]] = None,
                           predictors=None, diagnose: bool = False) -> Analysis:
    """Rung 2: the legacy [TP, CP] + LCD bracket without the simulator.

    Same single-sweep pipeline as ``full`` minus the ``sim`` stage — the
    fallback when the point prediction times out or faults.
    """
    preds = normalize_predictors(predictors)
    bracket_preds = tuple(p for p in preds if p != "sim") or ("tp",)
    analysis = analyze_kernel(kernel, model, unroll, checkpoint=checkpoint,
                              predictors=bracket_preds, diagnose=diagnose)
    return replace(analysis, degradation="bracket")


def analyze_kernel_tp_only(kernel: Kernel, model: MachineModel,
                           unroll: int = 1,
                           checkpoint: Optional[Callable[[str], None]] = None,
                           diagnose: bool = False) -> Analysis:
    """Rung 2: optimistic throughput only (the full-throughput model).

    No DAG, no CP/LCD sweeps, and no min-max scheduler — just cost
    resolution and the uniform-split port accumulation, the cheapest answer
    that still says something about port pressure.
    """
    check = checkpoint or _no_checkpoint
    check("resolve")
    costs = model.resolve_kernel(kernel)
    check("tp")
    tp = throughput_from_costs(costs, model, balanced=False)
    analysis = Analysis(kernel=kernel, model=model, unroll=unroll,
                        tp=tp, cp=None, lcd=None,
                        degradation="tp_only",
                        stages_completed=_RUNG_STAGES["tp_only"])
    if diagnose:
        analysis.findings = diagnose_analysis(analysis)
    return analysis


def analyze_kernel_parse_only(kernel: Kernel, model: MachineModel,
                              unroll: int = 1,
                              diagnose: bool = False) -> Analysis:
    """Rung 3: parse-level summary only — always answers.

    The kernel is already parsed when this runs (parsing failures are their
    own error class), so this rung never touches the machine DB and cannot
    time out: the floor of the degradation ladder.
    """
    analysis = Analysis(kernel=kernel, model=model, unroll=unroll,
                        tp=None, cp=None, lcd=None,
                        degradation="parse_only",
                        stages_completed=_RUNG_STAGES["parse_only"])
    if diagnose:
        # Nothing resolved → every emitter guards to empty, but `()` still
        # distinguishes "pass ran" from "pass not requested".
        analysis.findings = diagnose_analysis(analysis)
    return analysis


def analyze_kernel_rung(kernel: Kernel, model: MachineModel, unroll: int = 1,
                        rung: str = "full",
                        checkpoint: Optional[Callable[[str], None]] = None,
                        predictors=None, diagnose: bool = False) -> Analysis:
    """Run exactly one ladder rung (``full`` / ``bracket`` / ``tp_only`` /
    ``parse_only``).  ``predictors`` filters the ``full`` and ``bracket``
    rungs; the cheaper rungs are already fixed subsets."""
    if rung == "full":
        return analyze_kernel(kernel, model, unroll, checkpoint=checkpoint,
                              predictors=predictors, diagnose=diagnose)
    if rung == "bracket":
        return analyze_kernel_bracket(kernel, model, unroll,
                                      checkpoint=checkpoint,
                                      predictors=predictors,
                                      diagnose=diagnose)
    if rung == "tp_only":
        return analyze_kernel_tp_only(kernel, model, unroll,
                                      checkpoint=checkpoint,
                                      diagnose=diagnose)
    if rung == "parse_only":
        return analyze_kernel_parse_only(kernel, model, unroll,
                                         diagnose=diagnose)
    raise ValueError(
        f"unknown degradation rung '{rung}'; known: {DEGRADATION_LADDER}")


def analyze_kernel_ladder(kernel: Kernel, model: MachineModel, unroll: int = 1,
                          checkpoint: Optional[Callable[[str], None]] = None,
                          min_rung: str = "parse_only",
                          predictors=None, diagnose: bool = False) -> Analysis:
    """Walk the degradation ladder: try each rung down to ``min_rung``.

    A rung that raises (deadline expiry at a stage boundary, injected fault,
    analysis error) falls through to the next cheaper rung; ``parse_only``
    runs without checkpoints and therefore always answers.  Raises the last
    rung's error only when ``min_rung`` cuts the ladder short.
    """
    if min_rung not in DEGRADATION_LADDER:
        raise ValueError(
            f"unknown degradation rung '{min_rung}'; known: "
            f"{DEGRADATION_LADDER}")
    floor = DEGRADATION_LADDER.index(min_rung)
    last_error: Optional[BaseException] = None
    for rung in DEGRADATION_LADDER[:floor + 1]:
        try:
            return analyze_kernel_rung(kernel, model, unroll, rung=rung,
                                       checkpoint=checkpoint,
                                       predictors=predictors,
                                       diagnose=diagnose)
        except Exception as exc:  # noqa: BLE001 — fall one rung
            last_error = exc
    assert last_error is not None
    raise last_error


# -- batch API + process-level analysis cache --------------------------------


class LRUCache:
    """Small thread-safe LRU with hit/miss stats, shared by the analysis
    caches here and in ``repro.serving.analysis``."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._data: "OrderedDict[tuple, Analysis]" = OrderedDict()
        self._lock = threading.Lock()
        self.stats = {"hits": 0, "misses": 0}

    def get(self, key):
        with self._lock:
            hit = self._data.get(key)
            if hit is not None:
                self._data.move_to_end(key)
                self.stats["hits"] += 1
            return hit

    def put(self, key, value) -> None:
        """Record a miss and insert its result, evicting oldest entries."""
        with self._lock:
            self.stats["misses"] += 1
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def count_extra_hits(self, n: int = 1) -> None:
        """Account for requests satisfied by in-flight dedup (no lookup)."""
        with self._lock:
            self.stats["hits"] += n

    def evict(self, key) -> bool:
        """Drop one entry (fault injection simulates cache loss this way)."""
        with self._lock:
            return self._data.pop(key, None) is not None

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.stats["hits"] = self.stats["misses"] = 0


_cache = LRUCache(512)


def _mem_sig(refs) -> str:
    # Address-register structure of load/store operands: build_dag derives
    # address dependencies and writeback defs from these, so they are part
    # of a form's analysis identity.
    return ";".join(
        f"{ref.base.name if ref.base else ''}+"
        f"{ref.index.name if ref.index else ''}*{ref.scale}+{ref.offset}"
        f":{int(ref.post_index)}{int(ref.pre_index)}"
        for ref in refs)


def _form_text(form) -> str:
    # Parsed kernels carry the assembly text; programmatically built forms
    # (empty ``raw``) need a descriptor covering everything the analyses
    # read, or distinct kernels would collide in the cache.
    if form.raw:
        return form.raw
    return (f"{form.mnemonic}:{form.operand_signature()}"
            f":{','.join(form.source_registers)}"
            f">{','.join(form.dest_registers)}"
            f":{int(form.is_branch)}{int(form.is_dep_breaking)}"
            f"|L{_mem_sig(form.loads)}|S{_mem_sig(form.stores)}")


def _cache_key(kernel: Kernel, model: MachineModel, unroll: int,
               predictors: Tuple[str, ...] = PREDICTORS,
               diagnose: bool = False) -> tuple:
    # ``diagnose`` participates: a cached plain analysis must not satisfy a
    # diagnose=True request (its findings would be None, not computed).
    text = "\n".join(_form_text(form) for form in kernel)
    return (model.name, kernel.isa, unroll, predictors, bool(diagnose), text)


def clear_analysis_cache() -> None:
    _cache.clear()


def analyze_kernels(
    kernels: Iterable[Kernel],
    model: MachineModel,
    unroll: int = 1,
    use_cache: bool = True,
    predictors=None,
    diagnose: bool = False,
) -> List[Analysis]:
    """Analyze a batch of kernels against one machine model.

    Repeated kernel texts (the common case on a serving path: many requests
    for the same hot loop) hit a process-level LRU keyed by
    ``(model name, isa, unroll, predictors, diagnose, kernel text)``; all
    misses share
    the model's warm instruction-lookup memo, so a batch of *n* distinct
    kernels pays the instruction-DB probing cost once per distinct
    instruction form, not once per occurrence.

    Cache-identity caveat: machine models are assumed immutable after
    construction and distinguished by ``model.name`` (mutating a model's DB
    in place after analyses have been cached serves stale results).  A cache
    hit returns a per-request *view* carrying the requester's ``kernel.name``
    (the underlying TP/CP/LCD results are shared).
    """
    preds = normalize_predictors(predictors)
    out: List[Analysis] = []
    for kernel in kernels:
        if not use_cache:
            out.append(analyze_kernel(kernel, model, unroll=unroll,
                                      predictors=preds, diagnose=diagnose))
            continue
        key = _cache_key(kernel, model, unroll, preds, diagnose)
        hit = _cache.get(key)
        if hit is not None:
            out.append(analysis_view(hit, kernel.name))
            continue
        analysis = analyze_kernel(kernel, model, unroll=unroll,
                                  predictors=preds, diagnose=diagnose)
        _cache.put(key, analysis)
        out.append(analysis)
    return out


def analysis_view(analysis: Analysis, name: str) -> Analysis:
    """A shallow per-request view of a shared ``Analysis`` whose kernel
    carries the requester's name (results objects are shared, not copied)."""
    if analysis.kernel.name == name:
        return analysis
    view = replace(analysis, kernel=replace(analysis.kernel, name=name))
    memo = analysis.__dict__.get("_report_memo")
    if memo is not None:
        # Stamp the shared report snapshot with the requester's name: rows
        # and chains are immutable tuples, so the view costs O(1).
        view.__dict__["_report_memo"] = replace(memo, kernel_name=name)
    return view
