"""Combined OSACA analysis: TP + CP + LCD with a Table-II-style report.

Single-sweep pipeline: one ``resolve_kernel`` and one dual-writeback 2-copy
DAG build are shared across all three analyses — TP accumulates pressure from
the resolved costs, LCD runs the batched all-sources sweep over the DAG's
split-writeback view, and CP reuses the same DAG's copy-0 data-chained view.

``analyze_kernels`` is the batch entry point (one warm model cache across
kernels, process-level LRU keyed by kernel text + model name + unroll) for
serving paths that analyze many — often repeated — kernels concurrently.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, Iterable, List

from repro.core.analysis.critical_path import (CriticalPathResult,
                                               critical_path_from_dag)
from repro.core.analysis.dag import build_dag
from repro.core.analysis.lcd import LCDResult, lcd_from_dag
from repro.core.analysis.report import AnalysisReport
from repro.core.analysis.throughput import (ThroughputResult,
                                            throughput_from_costs)
from repro.core.isa.instruction import Kernel
from repro.core.machine.model import MachineModel


@dataclass
class Analysis:
    kernel: Kernel
    model: MachineModel
    unroll: int
    tp: ThroughputResult
    cp: CriticalPathResult
    lcd: LCDResult

    # Per high-level (source) iteration numbers — the paper's Table I units.
    @property
    def tp_per_it(self) -> float:
        return self.tp.per_iteration(self.unroll)

    @property
    def tp_balanced_per_it(self) -> float:
        """Min-max optimal-assignment throughput bound (cy per iteration)."""
        return self.tp.balanced_per_iteration(self.unroll)

    @property
    def cp_per_it(self) -> float:
        return self.cp.per_iteration(self.unroll)

    @property
    def lcd_per_it(self) -> float:
        return self.lcd.per_iteration(self.unroll)

    def prediction_bracket(self) -> Dict[str, float]:
        """[TP, CP] runtime bracket with the LCD as the expected value."""
        return {
            "lower_bound_tp": self.tp_per_it,
            "expected_lcd": self.lcd_per_it,
            "upper_bound_cp": self.cp_per_it,
        }

    def to_report(self) -> "AnalysisReport":
        """Snapshot into the serializable public-API report (memoized: on a
        serving path the same cached analysis is reported many times)."""
        report = self.__dict__.get("_report_memo")
        if report is None:
            report = AnalysisReport.from_analysis(self)
            self.__dict__["_report_memo"] = report
        return report

    def report(self) -> str:
        """Render a condensed Table-II-style report."""
        return self.to_report().render("text")


def analyze_kernel(kernel: Kernel, model: MachineModel, unroll: int = 1) -> Analysis:
    """Full TP/CP/LCD analysis: one cost resolution, one DAG build."""
    costs = model.resolve_kernel(kernel)
    dag = build_dag(kernel, model, copies=2, dual_writeback=True, costs=costs)
    return Analysis(
        kernel=kernel,
        model=model,
        unroll=unroll,
        tp=throughput_from_costs(costs, model),
        cp=critical_path_from_dag(dag),
        lcd=lcd_from_dag(dag, len(kernel)),
    )


# -- batch API + process-level analysis cache --------------------------------


class LRUCache:
    """Small thread-safe LRU with hit/miss stats, shared by the analysis
    caches here and in ``repro.serving.analysis``."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._data: "OrderedDict[tuple, Analysis]" = OrderedDict()
        self._lock = threading.Lock()
        self.stats = {"hits": 0, "misses": 0}

    def get(self, key):
        with self._lock:
            hit = self._data.get(key)
            if hit is not None:
                self._data.move_to_end(key)
                self.stats["hits"] += 1
            return hit

    def put(self, key, value) -> None:
        """Record a miss and insert its result, evicting oldest entries."""
        with self._lock:
            self.stats["misses"] += 1
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def count_extra_hits(self, n: int = 1) -> None:
        """Account for requests satisfied by in-flight dedup (no lookup)."""
        with self._lock:
            self.stats["hits"] += n

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.stats["hits"] = self.stats["misses"] = 0


_cache = LRUCache(512)


def _mem_sig(refs) -> str:
    # Address-register structure of load/store operands: build_dag derives
    # address dependencies and writeback defs from these, so they are part
    # of a form's analysis identity.
    return ";".join(
        f"{ref.base.name if ref.base else ''}+"
        f"{ref.index.name if ref.index else ''}*{ref.scale}+{ref.offset}"
        f":{int(ref.post_index)}{int(ref.pre_index)}"
        for ref in refs)


def _form_text(form) -> str:
    # Parsed kernels carry the assembly text; programmatically built forms
    # (empty ``raw``) need a descriptor covering everything the analyses
    # read, or distinct kernels would collide in the cache.
    if form.raw:
        return form.raw
    return (f"{form.mnemonic}:{form.operand_signature()}"
            f":{','.join(form.source_registers)}"
            f">{','.join(form.dest_registers)}"
            f":{int(form.is_branch)}{int(form.is_dep_breaking)}"
            f"|L{_mem_sig(form.loads)}|S{_mem_sig(form.stores)}")


def _cache_key(kernel: Kernel, model: MachineModel, unroll: int) -> tuple:
    text = "\n".join(_form_text(form) for form in kernel)
    return (model.name, kernel.isa, unroll, text)


def clear_analysis_cache() -> None:
    _cache.clear()


def analyze_kernels(
    kernels: Iterable[Kernel],
    model: MachineModel,
    unroll: int = 1,
    use_cache: bool = True,
) -> List[Analysis]:
    """Analyze a batch of kernels against one machine model.

    Repeated kernel texts (the common case on a serving path: many requests
    for the same hot loop) hit a process-level LRU keyed by
    ``(model name, isa, unroll, kernel text)``; all misses share the model's
    warm instruction-lookup memo, so a batch of *n* distinct kernels pays the
    instruction-DB probing cost once per distinct instruction form, not once
    per occurrence.

    Cache-identity caveat: machine models are assumed immutable after
    construction and distinguished by ``model.name`` (mutating a model's DB
    in place after analyses have been cached serves stale results).  A cache
    hit returns a per-request *view* carrying the requester's ``kernel.name``
    (the underlying TP/CP/LCD results are shared).
    """
    out: List[Analysis] = []
    for kernel in kernels:
        if not use_cache:
            out.append(analyze_kernel(kernel, model, unroll=unroll))
            continue
        key = _cache_key(kernel, model, unroll)
        hit = _cache.get(key)
        if hit is not None:
            out.append(analysis_view(hit, kernel.name))
            continue
        analysis = analyze_kernel(kernel, model, unroll=unroll)
        _cache.put(key, analysis)
        out.append(analysis)
    return out


def analysis_view(analysis: Analysis, name: str) -> Analysis:
    """A shallow per-request view of a shared ``Analysis`` whose kernel
    carries the requester's name (results objects are shared, not copied)."""
    if analysis.kernel.name == name:
        return analysis
    view = replace(analysis, kernel=replace(analysis.kernel, name=name))
    memo = analysis.__dict__.get("_report_memo")
    if memo is not None:
        # Stamp the shared report snapshot with the requester's name: rows
        # and chains are immutable tuples, so the view costs O(1).
        view.__dict__["_report_memo"] = replace(memo, kernel_name=name)
    return view
