"""Loop-carried dependency detection (paper §II-D), batched single sweep.

Two back-to-back copies of the loop body are analyzed with the same DAG
construction as the critical path; a dependency chain from an instruction form
in copy 0 to its own duplicate in copy 1 is a cyclic loop-carried dependency.
The longest such chain (one period's node-latency sum) bounds the achievable
overlap of successive iterations from below — the *expected* runtime for
dependency-bound kernels.

Engine: instead of one longest-path DP per body instruction (the seed's
O(n·(V+E)) loop, quadratic in kernel size), all n copy-0 source candidates
are swept at once.  A ``(n × V)`` distance matrix walks the 2-copy DAG in one
topological pass (node ids are already topological), each node reducing over
its predecessors with a vectorized ``max`` — O(V) sweep steps of
O(n · indeg) NumPy work, then one O(path) backtrack per source that actually
reaches its duplicate.  Results are bit-identical to the reference
per-source engine (see ``repro.core.analysis.reference`` and the equivalence
tests).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Set, Tuple

from repro.core.analysis.dag import DependencyDAG, build_dag
import numpy as np

from repro.core.analysis.sweep import (backtrack, batched_longest_paths,
                                       is_reached)
from repro.core.isa.instruction import Kernel
from repro.core.machine.model import InstructionCost, MachineModel


@dataclass
class LCDChain:
    length: float  # cycles per assembly-block iteration (one period)
    instr_indices: Tuple[int, ...]  # chain members (kernel body indices)
    carried_by: int  # the instruction index whose duplicate closes the cycle


@dataclass
class LCDResult:
    chains: Tuple[LCDChain, ...]
    longest: float  # cycles per assembly-block iteration (0 if no LCD)
    on_longest: Set[int]

    def per_iteration(self, unroll: int) -> float:
        return self.longest / unroll


def lcd_from_dag(dag: DependencyDAG, n_body: int) -> LCDResult:
    """Batched LCD over an already-built 2-copy DAG (its default adjacency
    must be the split-writeback LCD view)."""
    sources = []  # (body idx, copy-0 node, copy-1 node)
    for idx in range(n_body):
        src = dag.instr_node.get((idx, 0))
        dst = dag.instr_node.get((idx, 1))
        if src is None or dst is None:
            continue
        # A source with no consumers (or a duplicate nothing feeds) can never
        # close a cycle — don't spend a matrix row on it.
        if not dag.succs[src] or not dag.preds[dst]:
            continue
        sources.append((idx, src, dst))
    if not sources:
        return LCDResult(chains=(), longest=0.0, on_longest=set())

    ptr, idx_arr = dag.pred_csr()
    weights = dag.latency_vector()
    D, P = batched_longest_paths(ptr, idx_arr, weights,
                                 [[s] for _, s, _ in sources])
    P = np.ascontiguousarray(P)  # row-major for the per-source backtracks

    # body instr index per node for chain membership (-1 for load µ-ops).
    member_index = [n.instr_index if n.kind == "instr" else -1
                    for n in dag.nodes]
    seen: Dict[frozenset, LCDChain] = {}
    for row, (idx, src, dst) in enumerate(sources):
        if not is_reached(D[row, dst]):
            continue
        path_ids = backtrack(P[row], dst)
        if not path_ids or path_ids[0] != src:
            continue
        # One period: exclude the duplicate endpoint's latency.
        period = float(D[row, dst]) - dag.nodes[dst].latency
        members = tuple(member_index[v] for v in path_ids[:-1]
                        if member_index[v] >= 0)
        key = frozenset(members)
        if key not in seen or seen[key].length < period:
            seen[key] = LCDChain(length=period, instr_indices=members, carried_by=idx)

    chains = tuple(sorted(seen.values(), key=lambda c: -c.length))
    if chains:
        return LCDResult(chains=chains, longest=chains[0].length,
                         on_longest=set(chains[0].instr_indices))
    return LCDResult(chains=(), longest=0.0, on_longest=set())


def loop_carried_dependencies(
    kernel: Kernel,
    model: MachineModel,
    costs: Optional[Tuple[InstructionCost, ...]] = None,
) -> LCDResult:
    # Writeback address updates are independent µ-ops here (see dag.py): a
    # store's data register must not chain into later address uses, or the
    # steady-state cycle is overestimated (paper Table II LCD column).
    dag = build_dag(kernel, model, copies=2, writeback_chains_data=False,
                    costs=costs)
    return lcd_from_dag(dag, len(kernel))
