"""Loop-carried dependency detection (paper §II-D).

Two back-to-back copies of the loop body are analyzed with the same DAG
construction as the critical path; a dependency chain from an instruction form
in copy 0 to its own duplicate in copy 1 is a cyclic loop-carried dependency.
The longest such chain (one period's node-latency sum) bounds the achievable
overlap of successive iterations from below — the *expected* runtime for
dependency-bound kernels.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Set, Tuple

from repro.core.analysis.dag import DependencyDAG, Node, build_dag
from repro.core.isa.instruction import Kernel
from repro.core.machine.model import MachineModel


@dataclass
class LCDChain:
    length: float  # cycles per assembly-block iteration (one period)
    instr_indices: Tuple[int, ...]  # chain members (kernel body indices)
    carried_by: int  # the instruction index whose duplicate closes the cycle


@dataclass
class LCDResult:
    chains: Tuple[LCDChain, ...]
    longest: float  # cycles per assembly-block iteration (0 if no LCD)
    on_longest: Set[int]

    def per_iteration(self, unroll: int) -> float:
        return self.longest / unroll


def loop_carried_dependencies(kernel: Kernel, model: MachineModel) -> LCDResult:
    # Writeback address updates are independent µ-ops here (see dag.py): a
    # store's data register must not chain into later address uses, or the
    # steady-state cycle is overestimated (paper Table II LCD column).
    dag = build_dag(kernel, model, copies=2, writeback_chains_data=False)
    n_body = len(kernel)
    seen: Dict[frozenset, LCDChain] = {}

    for idx in range(n_body):
        src = dag.instr_node.get((idx, 0))
        dst = dag.instr_node.get((idx, 1))
        if src is None or dst is None:
            continue
        dist, parent = dag.longest_paths(sources=[src])
        if dist[dst] == float("-inf"):
            continue
        path_ids = dag.path_to(dst, parent)
        if not path_ids or path_ids[0] != src:
            continue
        # One period: exclude the duplicate endpoint's latency.
        period = dist[dst] - dag.nodes[dst].latency
        members = tuple(
            dag.nodes[v].instr_index for v in path_ids[:-1]
            if dag.nodes[v].kind == "instr"
        )
        key = frozenset(members)
        if key not in seen or seen[key].length < period:
            seen[key] = LCDChain(length=period, instr_indices=members, carried_by=idx)

    chains = tuple(sorted(seen.values(), key=lambda c: -c.length))
    if chains:
        return LCDResult(chains=chains, longest=chains[0].length,
                         on_longest=set(chains[0].instr_indices))
    return LCDResult(chains=(), longest=0.0, on_longest=set())
