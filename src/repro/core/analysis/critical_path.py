"""Critical-path extraction: longest node-weighted path in the dependency DAG
via weighted topological DP (Manber).  An upper bound on the runtime of one
instance of the loop body (paper §II-C).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Set, Tuple

from repro.core.analysis.dag import DependencyDAG, Node, build_dag
from repro.core.isa.instruction import Kernel
from repro.core.machine.model import MachineModel


@dataclass
class CriticalPathResult:
    length: float  # cycles per assembly-block iteration
    path: Tuple[Node, ...]
    # Set of instruction indices (within the kernel body) on the CP, for
    # Table-II-style per-line reporting.
    on_path: Set[int]

    def per_iteration(self, unroll: int) -> float:
        return self.length / unroll


def critical_path(kernel: Kernel, model: MachineModel) -> CriticalPathResult:
    dag = build_dag(kernel, model, copies=1)
    if not dag.nodes:
        return CriticalPathResult(length=0.0, path=(), on_path=set())
    dist, parent = dag.longest_paths()
    end = max(range(len(dag.nodes)), key=lambda v: dist[v])
    path_ids = dag.path_to(end, parent)
    path = tuple(dag.nodes[v] for v in path_ids)
    return CriticalPathResult(
        length=dist[end],
        path=path,
        on_path={n.instr_index for n in path if n.kind == "instr"},
    )
