"""Critical-path extraction: longest node-weighted path in the dependency DAG
via weighted topological DP (Manber).  An upper bound on the runtime of one
instance of the loop body (paper §II-C).

``critical_path_from_dag`` also accepts a shared dual-writeback 2-copy DAG
(from ``build_dag(..., dual_writeback=True)``): it then runs over the
data-chained CP view (``cp_preds``) and restricts path endpoints to copy-0
non-writeback nodes, which is exactly the 1-copy CP — so ``analyze_kernel``
can reuse the LCD's DAG instead of building a second one.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Set, Tuple

from repro.core.analysis.dag import DependencyDAG, Node, build_dag
from repro.core.analysis.sweep import NEG_INF, backtrack, single_longest_path
from repro.core.isa.instruction import Kernel
from repro.core.machine.model import InstructionCost, MachineModel


@dataclass
class CriticalPathResult:
    length: float  # cycles per assembly-block iteration
    path: Tuple[Node, ...]
    # Set of instruction indices (within the kernel body) on the CP, for
    # Table-II-style per-line reporting.
    on_path: Set[int]

    def per_iteration(self, unroll: int) -> float:
        return self.length / unroll


def critical_path_from_dag(dag: DependencyDAG) -> CriticalPathResult:
    """Longest path over the CP view, ending in a copy-0 non-writeback node."""
    if not dag.nodes:
        return CriticalPathResult(length=0.0, path=(), on_path=set())
    # Copy-0 nodes are an id prefix and have no incoming edges from later
    # copies, so the DP can stop at the copy boundary of a multi-copy DAG.
    n0 = len(dag.nodes)
    for v, node in enumerate(dag.nodes):
        if node.copy != 0:
            n0 = v
            break
    preds = dag.cp_preds if dag.cp_preds is not None else dag.preds
    weights = [n.latency for n in dag.nodes[:n0]]
    dist, parent = single_longest_path(preds[:n0], weights)
    end, best = -1, NEG_INF
    for v in range(n0):
        if dag.nodes[v].is_wb:
            continue
        if dist[v] > best:
            best, end = dist[v], v
    if end == -1:
        return CriticalPathResult(length=0.0, path=(), on_path=set())
    path_ids = backtrack(parent, end)
    path = tuple(dag.nodes[v] for v in path_ids)
    return CriticalPathResult(
        length=dist[end],
        path=path,
        on_path={n.instr_index for n in path if n.kind == "instr"},
    )


def critical_path(
    kernel: Kernel,
    model: MachineModel,
    costs: Optional[Tuple[InstructionCost, ...]] = None,
) -> CriticalPathResult:
    dag = build_dag(kernel, model, copies=1, costs=costs)
    return critical_path_from_dag(dag)
