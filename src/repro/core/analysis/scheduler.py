"""Optimal µ-op→port assignment: the *balanced* block-throughput bound.

The paper's §II-B model (``uniform()``) charges every instruction a *fixed*
``t/n`` pressure on each of its *n* equivalent ports.  That over-predicts
congestion whenever two instruction classes share only part of their port
sets: the hardware scheduler is free to push flexible work onto the less
contended ports.  The correct bound under perfect out-of-order scheduling is
the **min-max port load over all feasible fractional µ-op→port assignments**
— the restricted-assignment makespan LP, whose optimum has the classic
water-filling characterization

    T* = max over port subsets S of  demand(S) / |S|,

where ``demand(S)`` sums the cycles of µ-ops whose eligible ports all lie in
``S`` (work that *cannot* escape ``S``).  Single-port (pinned) µ-ops are just
singleton-eligibility classes, so pre-baked per-port DB entries fall out of
the same formula and make ``balanced == optimistic``.

The solver here peels tight sets iteratively (the water level drops after
each peel), evaluating each level's ``argmax`` over subsets with one
vectorized NumPy pass over a ``(classes × subsets)`` bitmask containment
matrix.  The subset space is ``2^k`` for ``k`` *contended* ports — ports
reachable by at least one multi-port µ-op — which is small on real machine
models (≤ 9 on the shipped DBs); ports that only ever receive pinned work
never enter the enumeration.

:func:`brute_force_min_max` is the differential-test oracle: an independent
pure-Python enumeration over *all* subsets of *all* relevant ports, no
peeling, no vectorization, no contended-port restriction.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from itertools import combinations
from typing import Dict, Iterable, List, Mapping, Sequence, Tuple

import numpy as np

#: Hard cap on the vectorized subset enumeration: 2^18 subsets ≈ 2 MB of
#: masks.  No shipped model comes close (k ≤ 9); a pathological custom model
#: beyond it falls back to enumerating only unions of eligibility sets.
_MAX_ENUM_PORTS = 18


@dataclass(frozen=True)
class BalancedSchedule:
    """Result of one kernel-global min-max port assignment."""

    bound: float  # optimal makespan T*: min over assignments of max port load
    port_load: Dict[str, float]  # per-port load under the optimal assignment
    bottleneck_port: str = ""
    #: Water-filling levels, outermost peel first: (level, ports) pairs.
    levels: Tuple[Tuple[float, Tuple[str, ...]], ...] = ()


def gather_classes(costs) -> Dict[frozenset, float]:
    """Aggregate a resolved kernel's µ-ops into eligibility classes.

    Returns ``{eligible port frozenset: total cycles}``.  Every cost part
    (arithmetic entry + split load/store µ-ops) contributes; parts without
    explicit ``uops`` contribute their ``pressure`` items as pinned
    single-port classes (the already-assigned fast path).  Macro-fused
    compares contribute nothing, mirroring ``InstructionCost.total_pressure``.
    """
    classes: Dict[frozenset, float] = {}
    for cost in costs:
        if cost.fused_away:
            continue
        for part in (cost.entry, cost.load, cost.store):
            if part is None:
                continue
            if part.uops is not None:
                for cycles, ports in part.uops:
                    if cycles:
                        key = frozenset(ports)
                        classes[key] = classes.get(key, 0.0) + cycles
            else:
                for port, cy in part.pressure.items():
                    if cy:
                        key = frozenset((port,))
                        classes[key] = classes.get(key, 0.0) + cy
    return classes


def _subset_masks(n_subsets: int) -> Tuple[np.ndarray, np.ndarray]:
    """All non-empty subset bitmasks of ``k`` ports plus their popcounts."""
    subs = np.arange(1, n_subsets, dtype=np.int64)
    sizes = np.zeros_like(subs)
    shifted = subs.copy()
    while shifted.any():
        sizes += shifted & 1
        shifted >>= 1
    return subs, sizes


def _union_closure(masks: Iterable[int], cap: int = 1 << 16) -> List[int]:
    """Closure of the eligibility masks under union (fallback search space
    for models with more contended ports than the dense enumeration allows).
    """
    closed = set(masks)
    frontier = list(closed)
    while frontier:
        m = frontier.pop()
        for other in list(closed):
            u = m | other
            if u not in closed:
                if len(closed) >= cap:
                    return sorted(closed)
                closed.add(u)
                frontier.append(u)
    return sorted(closed)


def _tight_set(demands: np.ndarray, masks: np.ndarray,
               candidates: np.ndarray, sizes: np.ndarray) -> Tuple[float, int]:
    """The water level and its tight port set: argmax demand(S)/|S|.

    One vectorized pass: a ``(classes × candidates)`` containment test
    (``class_mask & ~S == 0``) folds class demands into per-subset demand.
    """
    contained = (masks[:, None] & ~candidates[None, :]) == 0
    demand = demands @ contained
    ratios = demand / sizes
    best = int(np.argmax(ratios))
    return float(ratios[best]), int(candidates[best])


def min_max_load(classes: Mapping[frozenset, float],
                 ports: Sequence[str] = ()) -> BalancedSchedule:
    """Solve the fractional min-max port-load problem exactly.

    ``classes`` maps eligible port sets to total cycles of work; ``ports``
    (optional) fixes the key order of the returned ``port_load`` dict and
    adds zero-load entries for unused machine ports.

    Peeling loop: find the tightest subset ``S*`` (the highest water level),
    fix its ports at that level, drop ``S*``'s ports from every remaining
    class (an optimal schedule puts no escapable work on a saturated set),
    and repeat on the residual problem.
    """
    port_load: Dict[str, float] = {p: 0.0 for p in ports}
    levels: List[Tuple[float, Tuple[str, ...]]] = []

    # Pinned-only ports never interact with balancing decisions: their load
    # is their own demand.  Only ports reachable by a multi-port class join
    # the subset enumeration (as do pinned classes *on* those ports, which
    # raise the water level there).
    contended: set = set()
    for eligible in classes:
        if len(eligible) > 1:
            contended.update(eligible)
    pinned_only: Dict[str, float] = {}
    flex: Dict[frozenset, float] = {}
    for eligible, cycles in classes.items():
        if len(eligible) == 1 and next(iter(eligible)) not in contended:
            (port,) = eligible
            pinned_only[port] = pinned_only.get(port, 0.0) + cycles
        else:
            flex[eligible] = flex.get(eligible, 0.0) + cycles
    for port, cycles in pinned_only.items():
        port_load[port] = cycles

    order = sorted(contended)
    bit = {p: i for i, p in enumerate(order)}
    masks = np.array(
        [sum(1 << bit[p] for p in eligible) for eligible in flex],
        dtype=np.int64)
    demands = np.array([flex[eligible] for eligible in flex],
                       dtype=np.float64)

    dense = len(order) <= _MAX_ENUM_PORTS
    if dense and order:
        all_subs, all_sizes = _subset_masks(1 << len(order))
    while masks.size:
        if dense:
            # Restrict to subsets of the ports still in play.
            alive = 0
            for m in masks:
                alive |= int(m)
            keep = (all_subs & ~alive) == 0
            candidates, sizes = all_subs[keep], all_sizes[keep]
        else:
            candidates = np.array(_union_closure(int(m) for m in masks),
                                  dtype=np.int64)
            sizes = np.array([int(c).bit_count() for c in candidates],
                             dtype=np.int64)
        level, tight = _tight_set(demands, masks, candidates, sizes)
        for p, i in bit.items():
            if tight >> i & 1:
                port_load[p] = level
        levels.append(
            (level, tuple(p for p in order if tight >> bit[p] & 1)))
        keep = (masks & ~tight) != 0
        masks = masks[keep] & ~tight
        demands = demands[keep]

    bound = max(port_load.values(), default=0.0)
    bottleneck = ""
    if port_load:
        bottleneck = max(port_load, key=lambda p: port_load[p])
    return BalancedSchedule(bound=bound, port_load=port_load,
                            bottleneck_port=bottleneck,
                            levels=tuple(levels))


def balance_from_costs(costs, ports: Sequence[str] = ()) -> BalancedSchedule:
    """Kernel-global optimal assignment from resolved instruction costs."""
    return min_max_load(gather_classes(costs), ports)


# ---------------------------------------------------------------------------
# Differential-test oracle
# ---------------------------------------------------------------------------


def brute_force_min_max(classes: Mapping[frozenset, float]) -> float:
    """Independent enumeration oracle for the optimal makespan.

    Pure Python, no peeling, no vectorization, no contended-port restriction:
    evaluates ``demand(S)/|S|`` for *every* non-empty subset ``S`` of the
    full relevant port set.  Exponential in the port count — tests only.
    """
    ports = sorted({p for eligible in classes for p in eligible})
    best = 0.0
    for k in range(1, len(ports) + 1):
        for subset in combinations(ports, k):
            s = set(subset)
            demand = sum(cycles for eligible, cycles in classes.items()
                         if eligible <= s)
            best = max(best, demand / k)
    return best


def linprog_min_max(classes: Mapping[frozenset, float]):
    """LP oracle via ``scipy.optimize.linprog`` (``None`` if scipy missing).

    Variables: one assignment fraction per (class, eligible port) pair plus
    the makespan ``T``; minimize ``T`` subject to per-class conservation and
    per-port load ≤ ``T``.  Verifies *feasibility* of the combinatorial
    bound, not just the subset formula.
    """
    try:
        from scipy.optimize import linprog
    except ImportError:  # pragma: no cover - scipy is present in CI
        return None
    ports = sorted({p for eligible in classes for p in eligible})
    if not ports:
        return 0.0
    port_index = {p: i for i, p in enumerate(ports)}
    pairs = [(ci, port_index[p])
             for ci, eligible in enumerate(classes) for p in sorted(eligible)]
    n = len(pairs) + 1  # + T
    c = np.zeros(n)
    c[-1] = 1.0
    a_eq = np.zeros((len(classes), n))
    b_eq = np.array(list(classes.values()), dtype=np.float64)
    for col, (ci, _) in enumerate(pairs):
        a_eq[ci, col] = 1.0
    a_ub = np.zeros((len(ports), n))
    for col, (_, pi) in enumerate(pairs):
        a_ub[pi, col] = 1.0
    a_ub[:, -1] = -1.0
    res = linprog(c, A_ub=a_ub, b_ub=np.zeros(len(ports)),
                  A_eq=a_eq, b_eq=b_eq, bounds=[(0, None)] * n,
                  method="highs")
    return float(res.fun) if res.success else None
