"""Batched all-sources longest-path sweep over a topologically-ordered DAG.

The LCD analysis needs, for every candidate source ``s``, the longest
node-weighted path from ``s`` to every other node.  Running one DP per source
costs O(S·(V+E)) Python-interpreted work; instead we keep a ``(S × V)``
NumPy distance matrix and make a *single* forward sweep over node ids (ids
are already topological: every dependency edge points forward), reducing each
node's column from its predecessor columns with a vectorized
``max``-over-predecessors.  Total work is O(V) sweep steps of O(S · indeg)
vectorized arithmetic — one pass, regardless of how many sources there are.

Semantics match the reference scalar DP bit-for-bit, including tie-breaking:

* among equal-distance predecessors the *first* in insertion order wins
  (``argmax`` returns the first maximum, as the scalar ``>`` scan does);
* a source node starts at its own weight unless a longer (or equal) path
  from the row's allowed starts already reaches it — path-through wins ties.

The same helper drives the HLO while-body LCD
(:mod:`repro.core.hlo.lcd`), whose rows are loop-state tuple indices with
*multiple* allowed start nodes each.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

NEG_INF = float("-inf")

# Unreachable sentinel for the batched sweep.  A finite sentinel instead of
# -inf lets the inner loop skip reachability masks entirely: real path sums
# (|weight sums| < 1e12 in both the cycle and seconds domains) can never climb
# within 1e17 of it, and float64 has whole-number resolution ~128 at 1e18, so
# sentinel + weights stays far below REACH_THRESHOLD.
UNREACHABLE = -1.0e18
REACH_THRESHOLD = -1.0e17


def is_reached(value: float) -> bool:
    return value > REACH_THRESHOLD


def pred_csr_from_lists(preds: Sequence[Sequence[int]]) -> Tuple[np.ndarray, np.ndarray]:
    """Predecessor adjacency lists -> CSR ``(ptr, idx)`` in insertion order."""
    ptr = np.zeros(len(preds) + 1, dtype=np.int64)
    for v, p in enumerate(preds):
        ptr[v + 1] = ptr[v] + len(p)
    idx = np.fromiter((u for p in preds for u in p), dtype=np.int64,
                      count=int(ptr[-1]))
    return ptr, idx


def batched_longest_paths(
    ptr: np.ndarray,
    idx: np.ndarray,
    weights: np.ndarray,
    starts_per_row: Sequence[Sequence[int]],
) -> Tuple[np.ndarray, np.ndarray]:
    """Single-sweep longest paths from each row's allowed start set.

    ``ptr``/``idx`` is the predecessor CSR (node ids topologically ordered,
    edges forward); ``weights`` the per-node weight vector; row ``r`` may only
    start paths at nodes in ``starts_per_row[r]``.

    Returns ``(D, P)``: ``D[r, v]`` is the maximum weight sum over paths from
    ``starts_per_row[r]`` ending at ``v`` (below :data:`REACH_THRESHOLD` — see
    :func:`is_reached` — if unreachable), ``P[r, v]`` the predecessor of ``v``
    on that path (``-1`` at path starts; arbitrary junk on unreachable
    entries, which callers must filter with :func:`is_reached` first).
    """
    n = len(weights)
    n_rows = len(starts_per_row)
    # Node-major layout: D[v] is one contiguous row per node, so the
    # per-node predecessor gather reads (indeg × rows) contiguous rows and
    # writes one contiguous row — the sweep's whole working set streams.
    D = np.full((n, n_rows), UNREACHABLE, dtype=np.float64)
    P = np.full((n, n_rows), -1, dtype=np.int64)
    if n == 0 or n_rows == 0:
        return D.T, P.T

    # node id -> rows allowed to start there.
    start_rows: Dict[int, List[int]] = {}
    for r, starts in enumerate(starts_per_row):
        for v in starts:
            start_rows.setdefault(int(v), []).append(r)

    ptr_l = ptr.tolist()
    w_l = list(weights)
    cols = np.arange(n_rows)
    for v in range(n):
        lo, hi = ptr_l[v], ptr_l[v + 1]
        if hi - lo == 1:
            u = idx[lo]
            np.add(D[u], w_l[v], out=D[v])
            P[v] = u
        elif hi > lo:
            p = idx[lo:hi]
            sub = D[p]                          # (indeg × rows) gather
            arg = sub.argmax(axis=0)            # first max: scalar tie-break
            np.add(sub[arg, cols], w_l[v], out=D[v])
            P[v] = p[arg]
        rows = start_rows.get(v)
        if rows is not None:
            dv, pv = D[v], P[v]
            wv = w_l[v]
            for r in rows:
                # Path-through wins ties (strict <), matching the scalar DP.
                if dv[r] < wv:
                    dv[r] = wv
                    pv[r] = -1
    return D.T, P.T


def single_longest_path(
    preds: Sequence[Sequence[int]],
    weights: Sequence[float],
) -> Tuple[List[float], List[int]]:
    """Scalar all-starts longest path (every node may begin a path).

    The CP analysis needs just one unrestricted DP; a plain Python sweep over
    precomputed predecessor lists beats NumPy's per-node dispatch overhead at
    these graph sizes and keeps tie-breaking identical to the reference.
    """
    n = len(weights)
    dist = [0.0] * n
    parent = [-1] * n
    for v in range(n):
        best = NEG_INF
        best_pred = -1
        for u in preds[v]:
            if dist[u] > best:
                best = dist[u]
                best_pred = u
        if best == NEG_INF:
            dist[v] = weights[v]
        else:
            dist[v] = best + weights[v]
            parent[v] = best_pred
    return dist, parent


def backtrack(parent_row: Sequence[int], v: int) -> List[int]:
    """Follow parent pointers from ``v`` back to a path start; returns the
    node ids in forward order."""
    path: List[int] = []
    v = int(v)
    while v != -1:
        path.append(v)
        v = int(parent_row[v])
    path.reverse()
    return path
