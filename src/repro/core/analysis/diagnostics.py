"""Bottleneck diagnostics: from numbers to explanations (ROADMAP north star).

The analyses bracket a kernel's steady-state cost, but a bare number does not
say *why* the kernel is slow or what to do about it.  This pass walks a
finished :class:`~repro.core.analysis.analyze.Analysis` — the resolved costs,
the port-assignment solution, the LCD sweep, and the simulator trace — and
emits structured :class:`Finding` objects with a stable ``code``, a severity,
instruction-line anchors, a human-readable message, and a machine-readable
payload.  uiCA (arXiv:2107.14210) demonstrates the value of this kind of
sensitivity/bottleneck attribution for making throughput predictions
actionable; this is that layer over our bracket.

Finding codes (stable; new codes are additive):

``LCD_BOTTLENECK``
    The longest loop-carried dependency chain, naming its member
    instructions and each member's latency contribution to the cycle.
``PORT_HOTSPOT``
    The saturated port(s) under the optimal µ-op→port assignment, plus the
    eligibility classes whose work cannot escape them.
``DB_COVERAGE_GAP``
    Instruction forms that fell through every machine-DB probe to the
    default entry — their numbers are guesses, one finding per form.
``SIM_WINDOW_LIMITED``
    The window resource (frontend issue width / ROB / scheduler / LSQ) that
    bound the simulator's point prediction, with its capacity.
``SIM_CLAMPED``
    The simulator's raw steady state fell outside [TP, max(TP, CP)] and the
    headline prediction was clamped to a bracket edge.
``UNROLL_ADVICE``
    TP ⋘ CP: latency-bound code where unrolling would expose more
    independent work, with a suggested factor and the LCD floor.

Findings are deterministic for a given analysis (the ``DB_COVERAGE_GAP``
emitter reads the ``defaulted`` flags recorded on the resolved costs, not
the process-wide warn-once state) and ordered by (severity, code, first
anchor line).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.analysis.scheduler import gather_classes

#: Severity levels, most severe first (the report sort order).
SEVERITIES: Tuple[str, ...] = ("warning", "advice", "info")
_SEVERITY_RANK = {s: i for i, s in enumerate(SEVERITIES)}

#: Relative slack when comparing cycle quantities (water-filling levels are
#: exact up to float noise).
_REL_TOL = 1e-6

#: CP at least this multiple of the balanced TP marks latency-bound code
#: worth unrolling (the "TP ⋘ CP" trigger).
UNROLL_ADVICE_RATIO = 2.0

#: Cap on the suggested unroll factor: beyond this, register pressure and
#: frontend limits dominate anything the dependence structure promises.
MAX_SUGGESTED_UNROLL = 8

#: Simulator limiter values that name a finite window resource, mapped to
#: (human name, WindowParams field holding its capacity).
_WINDOW_RESOURCES: Dict[str, Tuple[str, str]] = {
    "frontend": ("frontend issue width", "issue_width"),
    "rob": ("re-order buffer", "rob_size"),
    "scheduler": ("scheduler queue", "sched_size"),
    "lsq": ("load/store queue", "lsq_size"),
}


@dataclass(frozen=True)
class Finding:
    """One structured diagnostic emitted by :func:`diagnose`.

    ``payload`` holds only plain JSON types (numbers, strings, bools, lists,
    dicts) so a finding round-trips bit-identically through the report's
    ``to_dict``/``from_dict``.
    """

    code: str
    severity: str  # one of SEVERITIES
    message: str
    lines: Tuple[int, ...] = ()  # source line-number anchors
    instrs: Tuple[int, ...] = ()  # kernel body instruction indices
    payload: Dict = field(default_factory=dict)

    def to_dict(self) -> Dict:
        return {
            "code": self.code,
            "severity": self.severity,
            "message": self.message,
            "lines": list(self.lines),
            "instrs": list(self.instrs),
            "payload": dict(self.payload),
        }

    @classmethod
    def from_dict(cls, data: Dict) -> "Finding":
        return cls(
            code=data["code"], severity=data["severity"],
            message=data["message"], lines=tuple(data.get("lines", ())),
            instrs=tuple(data.get("instrs", ())),
            payload=dict(data.get("payload", {})),
        )


def diagnose(analysis) -> Tuple[Finding, ...]:
    """All findings for one analysis, ordered most severe first.

    Works on any degradation rung: emitters that need a stage the rung did
    not run simply contribute nothing (absence of a finding never means the
    stage proved its absence — check ``stages_completed``).
    """
    findings: List[Finding] = []
    findings.extend(_lcd_bottleneck(analysis))
    findings.extend(_port_hotspot(analysis))
    findings.extend(_db_coverage_gap(analysis))
    findings.extend(_sim_findings(analysis))
    findings.extend(_unroll_advice(analysis))
    findings.sort(key=lambda f: (_SEVERITY_RANK.get(f.severity, len(SEVERITIES)),
                                 f.code, f.lines[:1] or (1 << 30,)))
    return tuple(findings)


def _costs(analysis):
    """Resolved per-instruction costs, or ``None`` below the tp rung."""
    if analysis.tp is None:
        return None
    return [cost for cost, _ in analysis.tp.per_instruction]


# -- LCD_BOTTLENECK ----------------------------------------------------------


def _lcd_bottleneck(analysis) -> List[Finding]:
    lcd = analysis.lcd
    costs = _costs(analysis)
    if lcd is None or not lcd.chains or costs is None:
        return []
    chain = lcd.chains[0]  # longest period
    edges = []
    for idx in chain.instr_indices:
        cost = costs[idx]
        edges.append({
            "index": idx,
            "line": cost.form.line_number,
            "mnemonic": cost.form.mnemonic,
            "latency": cost.entry.latency,
        })
    contributed = sum(e["latency"] for e in edges)
    # Split-load µ-ops on the chain carry latency but are not body members;
    # the residual attributes what the member latencies alone don't cover.
    residual = chain.length - contributed
    if abs(residual) <= _REL_TOL * max(chain.length, 1.0):
        residual = 0.0
    per_it = chain.length / max(analysis.unroll, 1)
    dominates = (analysis.tp is not None
                 and chain.length > analysis.tp.balanced_throughput
                 * (1.0 + _REL_TOL))
    path = " -> ".join(e["mnemonic"] for e in edges)
    message = (
        f"loop-carried dependency chain of {chain.length:.2f} cy/block "
        f"({per_it:.2f} cy/it) through {path}, carried back by instruction "
        f"{chain.carried_by}"
    )
    if residual:
        message += f" (+{residual:.2f} cy from split load µ-ops on the chain)"
    message += ("; the chain, not port pressure, bounds the steady state"
                if dominates else
                "; port pressure still dominates this chain")
    return [Finding(
        code="LCD_BOTTLENECK",
        severity="warning" if dominates else "info",
        message=message,
        lines=tuple(e["line"] for e in edges),
        instrs=tuple(chain.instr_indices),
        payload={
            "chain_cycles": chain.length,
            "per_iteration": per_it,
            "carried_by": chain.carried_by,
            "edges": edges,
            "residual_cycles": residual,
            "dominates_throughput": dominates,
            "n_chains": len(lcd.chains),
        },
    )]


# -- PORT_HOTSPOT ------------------------------------------------------------


def _port_hotspot(analysis) -> List[Finding]:
    tp = analysis.tp
    costs = _costs(analysis)
    if tp is None or costs is None or tp.balanced_throughput <= 0.0:
        return []
    bound = tp.balanced_throughput
    load = tp.balanced_port_load
    ports = tuple(analysis.model.ports)
    hot = [p for p in ports
           if load.get(p, 0.0) >= bound * (1.0 - _REL_TOL)]
    if not hot:
        return []
    hot_set = frozenset(hot)
    # Eligibility classes whose work cannot escape the hot set — the demand
    # that pins the water level there.
    saturating = []
    for eligible, cycles in sorted(gather_classes(costs).items(),
                                   key=lambda kv: (-kv[1], sorted(kv[0]))):
        if eligible <= hot_set and cycles > 0.0:
            saturating.append({"ports": sorted(eligible), "cycles": cycles})
    anchors = [(i, cost.form.line_number) for i, cost in enumerate(costs)
               if any(p in hot_set for p in cost.total_pressure)]
    lcd_block = analysis.lcd.longest if analysis.lcd is not None else 0.0
    # Ports are *the* bottleneck only when no dependency chain is longer.
    dominates = bound >= lcd_block * (1.0 - _REL_TOL)
    message = (
        f"port{'s' if len(hot) > 1 else ''} {', '.join(hot)} saturated at "
        f"{bound:.2f} cy/block under the optimal µ-op assignment; "
        f"{sum(c['cycles'] for c in saturating):.2f} cy of work is pinned to "
        f"{{{', '.join(sorted(hot_set))}}}"
    )
    message += ("; this resource limit bounds the steady state" if dominates
                else "; a longer dependency chain still dominates")
    return [Finding(
        code="PORT_HOTSPOT",
        severity="warning" if dominates else "info",
        message=message,
        lines=tuple(line for _, line in anchors),
        instrs=tuple(i for i, _ in anchors),
        payload={
            "bound": bound,
            "hot_ports": hot,
            "port_load": {p: load.get(p, 0.0) for p in ports},
            "utilization": {p: load.get(p, 0.0) / bound for p in ports},
            "saturating_classes": saturating,
            "dominates": dominates,
        },
    )]


# -- DB_COVERAGE_GAP ---------------------------------------------------------


def _db_coverage_gap(analysis) -> List[Finding]:
    costs = _costs(analysis)
    if costs is None:
        return []
    by_form: Dict[str, List[Tuple[int, int]]] = {}
    for idx, cost in enumerate(costs):
        if cost.defaulted:
            key = f"{cost.form.mnemonic}:{cost.form.operand_signature()}"
            by_form.setdefault(key, []).append((idx, cost.form.line_number))
    findings = []
    model = analysis.model
    for form_key in sorted(by_form):
        sites = by_form[form_key]
        findings.append(Finding(
            code="DB_COVERAGE_GAP",
            severity="warning",
            message=(
                f"no {model.name} DB entry for '{form_key}': default cost "
                f"(latency {model.default_entry.latency:g}, no port "
                f"pressure) used for {len(sites)} instruction(s) — every "
                f"bound involving them is a guess"
            ),
            lines=tuple(line for _, line in sites),
            instrs=tuple(idx for idx, _ in sites),
            payload={
                "form": form_key,
                "arch": model.name,
                "count": len(sites),
                "default_latency": model.default_entry.latency,
            },
        ))
    return findings


# -- SIM_WINDOW_LIMITED / SIM_CLAMPED ----------------------------------------


def _sim_findings(analysis) -> List[Finding]:
    sim = analysis.sim
    if sim is None:
        return []
    findings = []
    resource = _WINDOW_RESOURCES.get(sim.limiter)
    if resource is not None and sim.window is not None:
        name, attr = resource
        capacity = getattr(sim.window, attr)
        findings.append(Finding(
            code="SIM_WINDOW_LIMITED",
            severity="info",
            message=(
                f"point prediction ({sim.cy_per_block:.2f} cy/block) is "
                f"limited by the {name} ({attr}={capacity}): the out-of-order "
                f"window, not ports or dependencies, binds the steady state"
            ),
            payload={
                "limiter": sim.limiter,
                "resource": name,
                "capacity_field": attr,
                "capacity": capacity,
                "cy_per_block": sim.cy_per_block,
                "window": sim.window.to_dict(),
            },
        ))
    if sim.clamped_to:
        edge = "TP lower bound" if sim.clamped_to == "tp" else "CP upper bound"
        findings.append(Finding(
            code="SIM_CLAMPED",
            severity="info",
            message=(
                f"simulator steady state measured {sim.raw_cy_per_block:.2f} "
                f"cy/block outside the bracket; headline prediction clamped "
                f"to the {edge} ({sim.cy_per_block:.2f} cy/block, "
                f"{sim.limiter or 'unknown'}-limited)"
            ),
            payload={
                "raw_block": sim.raw_cy_per_block,
                "clamped_block": sim.cy_per_block,
                "edge": sim.clamped_to,
                "limiter": sim.limiter,
                "converged": sim.converged,
            },
        ))
    return findings


# -- UNROLL_ADVICE -----------------------------------------------------------


def _unroll_advice(analysis) -> List[Finding]:
    tp, cp = analysis.tp, analysis.cp
    if tp is None or cp is None:
        return []
    unroll = max(analysis.unroll, 1)
    tp_it = tp.balanced_throughput / unroll
    cp_it = cp.length / unroll
    if tp_it <= 0.0 or cp_it < UNROLL_ADVICE_RATIO * tp_it:
        return []
    lcd_it = (analysis.lcd.longest / unroll
              if analysis.lcd is not None else 0.0)
    suggested = min(MAX_SUGGESTED_UNROLL,
                    max(2, math.ceil(cp_it / tp_it)))
    floor_it = max(tp_it, lcd_it)
    message = (
        f"latency-bound: CP {cp_it:.2f} cy/it is {cp_it / tp_it:.1f}x the "
        f"balanced TP bound {tp_it:.2f} cy/it — ports sit idle waiting on "
        f"dependencies; unrolling ~{suggested}x exposes more independent "
        f"work"
    )
    if lcd_it > tp_it * (1.0 + _REL_TOL):
        message += (f" (floor: the loop-carried chain still costs "
                    f"{lcd_it:.2f} cy/it)")
    return [Finding(
        code="UNROLL_ADVICE",
        severity="advice",
        message=message,
        payload={
            "tp_balanced_per_it": tp_it,
            "cp_per_it": cp_it,
            "ratio": cp_it / tp_it,
            "suggested_unroll": suggested,
            "floor_per_it": floor_it,
            "lcd_per_it": lcd_it,
        },
    )]
