from repro.core.analysis.throughput import ThroughputResult, throughput_analysis
from repro.core.analysis.dag import DependencyDAG, Node, build_dag
from repro.core.analysis.critical_path import CriticalPathResult, critical_path
from repro.core.analysis.lcd import LCDResult, loop_carried_dependencies
from repro.core.analysis.analyze import Analysis, analyze_kernel

__all__ = [
    "Analysis",
    "CriticalPathResult",
    "DependencyDAG",
    "LCDResult",
    "Node",
    "ThroughputResult",
    "analyze_kernel",
    "build_dag",
    "critical_path",
    "loop_carried_dependencies",
    "throughput_analysis",
]
