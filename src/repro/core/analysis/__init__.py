from repro.core.analysis.throughput import (ThroughputResult,
                                            throughput_analysis,
                                            throughput_from_costs)
from repro.core.analysis.scheduler import (BalancedSchedule,
                                           balance_from_costs,
                                           brute_force_min_max,
                                           gather_classes, min_max_load)
from repro.core.analysis.dag import DependencyDAG, Node, build_dag
from repro.core.analysis.critical_path import (CriticalPathResult,
                                               critical_path,
                                               critical_path_from_dag)
from repro.core.analysis.lcd import (LCDResult, lcd_from_dag,
                                     loop_carried_dependencies)
from repro.core.analysis.analyze import (Analysis, analysis_view,
                                         analyze_kernel, analyze_kernels,
                                         clear_analysis_cache)
from repro.core.analysis.report import (AnalysisReport, InstructionRow,
                                        LCDChainRow, SCHEMA_VERSION)
from repro.core.analysis.render import register_renderer, render

__all__ = [
    "Analysis",
    "AnalysisReport",
    "BalancedSchedule",
    "balance_from_costs",
    "brute_force_min_max",
    "gather_classes",
    "min_max_load",
    "InstructionRow",
    "LCDChainRow",
    "SCHEMA_VERSION",
    "analysis_view",
    "register_renderer",
    "render",
    "CriticalPathResult",
    "DependencyDAG",
    "LCDResult",
    "Node",
    "ThroughputResult",
    "analyze_kernel",
    "analyze_kernels",
    "build_dag",
    "clear_analysis_cache",
    "critical_path",
    "critical_path_from_dag",
    "lcd_from_dag",
    "loop_carried_dependencies",
    "throughput_analysis",
    "throughput_from_costs",
]
