"""OSACA-JAX core: the paper's static throughput / critical-path / LCD
analysis, for x86 + AArch64 assembly (faithful reproduction) and for XLA HLO
on TPU meshes (the framework-integrated adaptation, ``repro.core.hlo``)."""

from repro.core.analysis import analyze_kernel, analyze_kernels
from repro.core.isa import parse_aarch64, parse_x86
from repro.core.machine import cascade_lake, thunderx2, zen

__all__ = ["analyze_kernel", "analyze_kernels", "parse_aarch64", "parse_x86",
           "cascade_lake", "thunderx2", "zen"]
