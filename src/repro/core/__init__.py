"""OSACA-JAX core: the paper's static throughput / critical-path / LCD
analysis, for x86 + AArch64 assembly (faithful reproduction) and for XLA HLO
on TPU meshes (the framework-integrated adaptation, ``repro.core.hlo``)."""

from repro.core.analysis import (AnalysisReport, analyze_kernel,
                                 analyze_kernels)
from repro.core.isa import parse_aarch64, parse_x86
from repro.core.machine import cascade_lake, thunderx2, zen
from repro.core.registry import (ArchSpec, asm_arch_ids, get_arch,
                                 list_arch_ids, register_arch)

__all__ = ["AnalysisReport", "ArchSpec", "analyze_kernel", "analyze_kernels",
           "asm_arch_ids", "cascade_lake", "get_arch", "list_arch_ids",
           "parse_aarch64", "parse_x86", "register_arch", "thunderx2", "zen"]
