"""Declarative out-of-order port model (paper §II).

A :class:`MachineModel` is a set of named issue ports plus an instruction
database mapping instruction forms to ``(latency, port pressure)``.  Port
pressure follows the paper's fixed-probability rule: an instruction form that
may execute on *n* equivalent ports with inverse throughput *t* contributes
``t/n`` cycles to each of them (helper :func:`uniform`); forms with known
µ-op→port mappings carry explicit per-port cycles instead.

Memory-operand splitting (paper §II): an arithmetic instruction with a memory
source/destination is decomposed into its arithmetic part plus the machine's
generic load/store part; pressures add, and the load becomes a separate DAG
vertex carrying the load latency (§II-C rule 4).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

from repro.core.isa.instruction import InstructionForm
from repro.core.machine.window import WindowParams

# Unknown (model name, mnemonic:signature) pairs already warned about, so a
# missing entry is reported once per process instead of per occurrence.
_WARNED_DEFAULTS: set = set()


def uniform(ports: Tuple[str, ...], inverse_throughput: float = 1.0) -> Dict[str, float]:
    """Fixed-probability pressure: spread ``inverse_throughput`` cycles evenly."""
    share = inverse_throughput / len(ports)
    return {p: share for p in ports}


#: One µ-op: ``(cycles, eligible ports)`` — ``cycles`` of work that may be
#: scheduled fractionally across any of the named ports.
Uop = Tuple[float, Tuple[str, ...]]


@dataclass(frozen=True)
class DBEntry:
    """Instruction-database record for one instruction form.

    ``pressure`` is the paper's fixed-probability per-port split (the
    *optimistic* uniform model).  ``uops``, when present, is the richer form:
    the instruction's µ-ops with their *eligible port sets*, which the
    min-max scheduler (:mod:`repro.core.analysis.scheduler`) assigns
    kernel-globally.  Entries without ``uops`` (pre-baked per-port floats)
    are treated as already assigned: each ``pressure`` item is pinned to its
    port, so the balanced bound degenerates to the optimistic one.
    """

    latency: float
    pressure: Mapping[str, float]
    # Inverse throughput in cycles (informational; the pressure already
    # encodes it).  Defaults to the pressure sum.
    throughput: Optional[float] = None
    note: str = ""
    uops: Optional[Tuple[Uop, ...]] = None

    @property
    def inverse_throughput(self) -> float:
        if self.throughput is not None:
            return self.throughput
        return max(self.pressure.values()) if self.pressure else 0.0

    def combined_with(self, other: "DBEntry", note: str = "") -> "DBEntry":
        pressure = dict(self.pressure)
        for port, cy in other.pressure.items():
            pressure[port] = pressure.get(port, 0.0) + cy
        uops = None
        if self.uops is not None or other.uops is not None:
            uops = (pressure_uops(self.pressure) if self.uops is None
                    else self.uops)
            uops += (pressure_uops(other.pressure) if other.uops is None
                     else other.uops)
        return DBEntry(latency=self.latency, pressure=pressure, note=note,
                       uops=uops)


def pressure_uops(pressure: Mapping[str, float]) -> Tuple[Uop, ...]:
    """Pre-baked per-port floats as already-assigned (single-port) µ-ops."""
    return tuple((cy, (port,)) for port, cy in pressure.items() if cy)


def uops_entry(latency: float, uops, throughput: Optional[float] = None,
               note: str = "") -> DBEntry:
    """Build a :class:`DBEntry` from µ-ops with eligible port sets.

    The uniform-split ``pressure`` is derived (``cycles / len(ports)`` on each
    eligible port), so an entry converted from ``uniform()`` form keeps its
    optimistic per-port numbers bit-identical.
    """
    norm: list = []
    pressure: Dict[str, float] = {}
    for cycles, ports in uops:
        ports = tuple(ports)
        if not ports:
            raise ValueError("µ-op with empty eligible port set")
        norm.append((float(cycles), ports))
        share = float(cycles) / len(ports)
        for p in ports:
            pressure[p] = pressure.get(p, 0.0) + share
    return DBEntry(latency=latency, pressure=pressure, throughput=throughput,
                   note=note, uops=tuple(norm))


@dataclass
class InstructionCost:
    """Resolved cost of one parsed instruction, after memory splitting."""

    form: InstructionForm
    entry: DBEntry  # arithmetic/primary part (node latency for CP/LCD)
    load: Optional[DBEntry] = None  # split-off load part, if any
    store: Optional[DBEntry] = None  # split-off store part, if any
    fused_away: bool = False  # macro-fused compare: contributes no pressure
    # True when no DB entry matched and the machine default was used: every
    # number derived from this cost is a guess, which the diagnostics pass
    # surfaces as a DB_COVERAGE_GAP finding.
    defaulted: bool = False

    @property
    def total_pressure(self) -> Dict[str, float]:
        if self.fused_away:
            return {}
        pressure: Dict[str, float] = dict(self.entry.pressure)
        for part in (self.load, self.store):
            if part is not None:
                for port, cy in part.pressure.items():
                    pressure[port] = pressure.get(port, 0.0) + cy
        return pressure


@dataclass
class MachineModel:
    name: str
    isa: str  # "x86" | "aarch64"
    ports: Tuple[str, ...]
    db: Dict[str, DBEntry]
    # Generic split parts for memory operands embedded in arithmetic forms.
    load_entry: DBEntry = None  # type: ignore[assignment]
    store_entry: DBEntry = None  # type: ignore[assignment]
    # cmp/test + conditional-jump macro fusion (Intel/AMD x86 cores).
    macro_fusion: bool = False
    fused_branch_pressure: Mapping[str, float] = field(default_factory=dict)
    default_entry: DBEntry = field(
        default_factory=lambda: DBEntry(latency=1.0, pressure={}, note="default")
    )
    frequency_ghz: float = 2.5
    # Out-of-order window capacities for the point-prediction simulator
    # (repro.core.sim).  ``None`` means "no window model": the simulator is
    # skipped for this machine and analyses fall back to the [TP, CP] bracket.
    window: Optional[WindowParams] = None
    # Memoized lookup results keyed by (mnemonic, signature, has_loads,
    # has_stores): repeated instruction forms (every copy of every unrolled
    # instance) resolve to the same (entry, load, store, defaulted) parts,
    # so probing the DB once per distinct form is enough.
    _lookup_cache: Dict[tuple, tuple] = field(
        default_factory=dict, repr=False, compare=False)
    # Running count of default-entry fallbacks per ``mnemonic:signature``
    # form, bumped on *every* lookup (memo hits included) so callers can
    # diff the counter around a resolve and attribute gaps per analysis.
    fallbacks: Dict[str, int] = field(
        default_factory=dict, repr=False, compare=False)

    # -- lookup ------------------------------------------------------------

    def lookup(self, form: InstructionForm) -> InstructionCost:
        """Resolve a parsed instruction form to its cost record.

        Lookup order: exact ``mnemonic:signature``; the signature with memory
        operands substituted by their register class (plus generic load/store
        split); bare ``mnemonic``; machine default (with a warning, once per
        unknown ``(model, mnemonic:signature)`` pair).
        """
        sig = form.operand_signature()
        cache_key = (form.mnemonic, sig, bool(form.loads), bool(form.stores))
        parts = self._lookup_cache.get(cache_key)
        if parts is None:
            parts = self._lookup_parts(form, sig)
            # Crude bound for long-lived serving processes fed caller-
            # controlled asm: distinct unknown forms must not grow the memo
            # (and the warn-once set below) without limit.
            if len(self._lookup_cache) >= 1 << 16:
                self._lookup_cache.clear()
            self._lookup_cache[cache_key] = parts
        entry, load, store, defaulted = parts
        if defaulted:
            form_key = f"{form.mnemonic}:{sig}"
            if len(self.fallbacks) >= 1 << 16:
                self.fallbacks.clear()
            self.fallbacks[form_key] = self.fallbacks.get(form_key, 0) + 1
        return InstructionCost(form=form, entry=entry, load=load, store=store,
                               defaulted=defaulted)

    def _lookup_parts(self, form: InstructionForm, sig: str):
        """Uncached DB probe; returns ``(entry, load, store, defaulted)``."""
        key = f"{form.mnemonic}:{sig}"
        if key in self.db:
            return self.db[key], None, None, False

        if "m" in sig:
            # Try register-form entry + split load/store µ-ops.
            for repl in ("f", "r", "v"):
                reg_key = f"{form.mnemonic}:{sig.replace('m', repl)}"
                if reg_key in self.db:
                    return (self.db[reg_key],
                            self.load_entry if form.loads else None,
                            self.store_entry if form.stores else None,
                            False)

        if form.mnemonic in self.db:
            return self.db[form.mnemonic], None, None, False

        # Mnemonic-family fallback (e.g. ``b.ne`` -> ``b``).
        family = form.mnemonic.split(".")[0]
        if family in self.db:
            return self.db[family], None, None, False

        if (self.name, key) not in _WARNED_DEFAULTS:
            if len(_WARNED_DEFAULTS) >= 1 << 16:
                _WARNED_DEFAULTS.clear()
            _WARNED_DEFAULTS.add((self.name, key))
            warnings.warn(
                f"[{self.name}] no DB entry for '{key}'; using default "
                f"(latency={self.default_entry.latency})",
                stacklevel=3,
            )
        return self.default_entry, None, None, True

    def resolve_kernel(self, kernel) -> Tuple[InstructionCost, ...]:
        """Resolve all instructions, applying macro fusion peepholes."""
        costs = [self.lookup(form) for form in kernel]
        if self.macro_fusion:
            for i in range(len(costs) - 1):
                a, b = costs[i], costs[i + 1]
                if a.form.mnemonic.startswith(("cmp", "test")) and b.form.is_branch:
                    costs[i] = InstructionCost(form=a.form, entry=a.entry,
                                               fused_away=True,
                                               defaulted=a.defaulted)
                    costs[i + 1] = InstructionCost(
                        form=b.form,
                        entry=DBEntry(
                            latency=b.entry.latency,
                            pressure=dict(self.fused_branch_pressure),
                            note="macro-fused cmp+jcc",
                        ),
                        defaulted=b.defaulted,
                    )
        return tuple(costs)
