"""Machine-DB / ISA consistency linter (``python -m repro.core.machine.lint``).

Every prediction this repo makes is driven by hand-maintained machine
description tables — latencies, µ-op port sets, window capacities, the arch
registry's alias map.  Kerncraft (arXiv:1509.03778) treats machine-description
validation as a first-class pass for exactly this reason: a typo'd port name
or a negative latency does not crash anything, it silently corrupts every
bound downstream.  This module cross-checks the tables statically:

Per machine model (:func:`lint_model`):

``UNDECLARED_PORT``      a µ-op port set or pressure entry names a port the
                         model never declared (work charged to nowhere).
``DUPLICATE_PORT``       the declared port tuple repeats a name.
``NEGATIVE_LATENCY``     an entry's latency is negative or NaN.
``IMPLAUSIBLE_LATENCY``  latency above :data:`MAX_PLAUSIBLE_LATENCY` cycles
                         (warning — nothing on a real core is that slow
                         short of a page walk).
``NEGATIVE_PRESSURE``    a per-port pressure value is negative or NaN.
``EMPTY_UOP_PORTS``      a µ-op with no eligible port (unschedulable work).
``UOP_PRESSURE_MISMATCH``the stored uniform-split pressure disagrees with
                         what the entry's µ-ops derive (the two models the
                         analyses read would disagree with each other).
``THROUGHPUT_INCONSISTENT`` an explicit inverse throughput below what the
                         entry's own µ-ops can sustain (or negative).
``WINDOW_BOUNDS``        ``WindowParams`` violates its validated ordering
                         (a constructor bypass — the simulator would model
                         nonsense capacities).
``NO_WINDOW``            no window parameters (warning: the simulator is
                         skipped for this machine).
``FUSION_NO_PRESSURE``   macro fusion enabled but no fused-branch pressure
                         (fused pairs would execute for free).
``BAD_FREQUENCY``        non-positive clock frequency.

Registry (:func:`lint_registry`):

``ALIAS_CYCLE``          alias resolution loops without reaching a
                         registered id.
``DANGLING_ALIAS``       an alias maps to an id the registry doesn't hold.
``SELF_RESOLUTION``      a registered id whose own normalized name resolves
                         to a different id.
``NO_PARSER``            a non-HLO spec without a parser.
``MODEL_MISMATCH``       the spec's isa/id disagree with the model its
                         factory builds.

Run as a CI gate::

    python -m repro.core.machine.lint --strict

``--strict`` fails on warnings too; the default fails only on errors.
"""

from __future__ import annotations

import argparse
import math
import sys
from dataclasses import dataclass
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

from repro.core.machine.model import DBEntry, MachineModel

#: Per-entry latencies above this many cycles are flagged as implausible
#: (warning).  The slowest shipped entry is a 23-cycle divide; a hundred-
#: cycle-plus "latency" is almost always a typo'd extra digit.
MAX_PLAUSIBLE_LATENCY = 128.0

#: Tolerance when comparing derived vs stored pressure (both come from the
#: same float arithmetic, so exact-ish agreement is expected).
_TOL = 1e-9


@dataclass(frozen=True)
class LintIssue:
    """One linter diagnostic."""

    severity: str  # "error" | "warning"
    arch: str  # model name or "registry"
    code: str
    subject: str  # DB key / alias / field the issue anchors to
    message: str

    def __str__(self) -> str:
        return (f"[{self.severity}] {self.arch}: {self.code} ({self.subject}) "
                f"— {self.message}")


def _bad_number(value) -> bool:
    try:
        return math.isnan(float(value))
    except (TypeError, ValueError):
        return True


def _entry_min_throughput(entry: DBEntry) -> float:
    """The fastest inverse throughput the entry's own work allows: the
    min-max makespan of its µ-ops considered alone."""
    from repro.core.analysis.scheduler import min_max_load
    classes: Dict[frozenset, float] = {}
    if entry.uops is not None:
        pairs = [(cy, tuple(ports)) for cy, ports in entry.uops]
    else:
        pairs = [(cy, (port,)) for port, cy in entry.pressure.items()]
    for cycles, ports in pairs:
        if not ports or not cycles:
            continue
        key = frozenset(ports)
        classes[key] = classes.get(key, 0.0) + float(cycles)
    if not classes:
        return 0.0
    return min_max_load(classes).bound


def _lint_entry(arch: str, key: str, entry: DBEntry,
                declared: frozenset) -> List[LintIssue]:
    issues: List[LintIssue] = []

    def err(code: str, message: str) -> None:
        issues.append(LintIssue("error", arch, code, key, message))

    def warn(code: str, message: str) -> None:
        issues.append(LintIssue("warning", arch, code, key, message))

    if _bad_number(entry.latency) or entry.latency < 0:
        err("NEGATIVE_LATENCY", f"latency {entry.latency!r} is not a "
            f"non-negative number")
    elif entry.latency > MAX_PLAUSIBLE_LATENCY:
        warn("IMPLAUSIBLE_LATENCY",
             f"latency {entry.latency:g} cy exceeds the plausibility cap "
             f"{MAX_PLAUSIBLE_LATENCY:g} — typo'd digit?")

    for port, cy in entry.pressure.items():
        if port not in declared:
            err("UNDECLARED_PORT",
                f"pressure names undeclared port '{port}' "
                f"(declared: {', '.join(sorted(declared))})")
        if _bad_number(cy) or cy < 0:
            err("NEGATIVE_PRESSURE",
                f"pressure on '{port}' is {cy!r}, not a non-negative number")

    if entry.uops is not None:
        derived: Dict[str, float] = {}
        for cycles, ports in entry.uops:
            if not ports:
                err("EMPTY_UOP_PORTS",
                    f"µ-op of {cycles!r} cy has an empty eligible port set "
                    f"(unschedulable work)")
                continue
            if _bad_number(cycles) or cycles < 0:
                err("NEGATIVE_PRESSURE",
                    f"µ-op cycles {cycles!r} is not a non-negative number")
                continue
            share = float(cycles) / len(ports)
            for port in ports:
                if port not in declared:
                    err("UNDECLARED_PORT",
                        f"µ-op names undeclared port '{port}' "
                        f"(declared: {', '.join(sorted(declared))})")
                derived[port] = derived.get(port, 0.0) + share
        stored = {p: cy for p, cy in entry.pressure.items() if cy}
        derived = {p: cy for p, cy in derived.items() if cy}
        if set(stored) != set(derived) or any(
                abs(stored[p] - derived[p]) > _TOL for p in stored):
            err("UOP_PRESSURE_MISMATCH",
                f"stored uniform-split pressure {stored} disagrees with the "
                f"µ-op derivation {derived}; the optimistic and balanced "
                f"bounds would read different machines")

    if entry.throughput is not None:
        if _bad_number(entry.throughput) or entry.throughput < 0:
            err("THROUGHPUT_INCONSISTENT",
                f"explicit inverse throughput {entry.throughput!r} is not a "
                f"non-negative number")
        else:
            floor = _entry_min_throughput(entry)
            if entry.throughput < floor - _TOL:
                err("THROUGHPUT_INCONSISTENT",
                    f"explicit inverse throughput {entry.throughput:g} cy is "
                    f"below the {floor:g} cy its own µ-ops sustain at best")
    return issues


def lint_model(model: MachineModel) -> List[LintIssue]:
    """All issues for one machine model (DB entries + window + structure)."""
    issues: List[LintIssue] = []
    arch = model.name

    def err(code: str, subject: str, message: str) -> None:
        issues.append(LintIssue("error", arch, code, subject, message))

    def warn(code: str, subject: str, message: str) -> None:
        issues.append(LintIssue("warning", arch, code, subject, message))

    declared = frozenset(model.ports)
    if len(model.ports) != len(declared):
        dupes = sorted({p for p in model.ports if model.ports.count(p) > 1})
        err("DUPLICATE_PORT", "ports",
            f"port tuple repeats {', '.join(dupes)}")
    if not declared:
        err("DUPLICATE_PORT", "ports", "model declares no ports")

    entries: List[Tuple[str, Optional[DBEntry]]] = list(model.db.items())
    entries += [("<load_entry>", model.load_entry),
                ("<store_entry>", model.store_entry),
                ("<default_entry>", model.default_entry)]
    for key, entry in entries:
        if entry is None:
            err("MISSING_ENTRY", key, "entry is None")
            continue
        issues.extend(_lint_entry(arch, key, entry, declared))

    for port, cy in dict(model.fused_branch_pressure).items():
        if port not in declared:
            err("UNDECLARED_PORT", "<fused_branch_pressure>",
                f"names undeclared port '{port}'")
        if _bad_number(cy) or cy < 0:
            err("NEGATIVE_PRESSURE", "<fused_branch_pressure>",
                f"pressure on '{port}' is {cy!r}")
    if model.macro_fusion and not any(model.fused_branch_pressure.values()):
        warn("FUSION_NO_PRESSURE", "<fused_branch_pressure>",
             "macro fusion enabled but fused branches carry no port "
             "pressure — fused pairs would execute for free")

    if _bad_number(model.frequency_ghz) or model.frequency_ghz <= 0:
        err("BAD_FREQUENCY", "frequency_ghz",
            f"clock frequency {model.frequency_ghz!r} GHz is not positive")

    if model.window is None:
        warn("NO_WINDOW", "window",
             "no window parameters — the OoO simulator is skipped for this "
             "machine")
    else:
        try:
            model.window.validate()
        except ValueError as exc:
            err("WINDOW_BOUNDS", "window", str(exc))
    return issues


def lint_registry(names: Optional[Mapping[str, str]] = None,
                  registry: Optional[Mapping] = None) -> List[LintIssue]:
    """Consistency of the arch registry's alias table.

    ``names`` / ``registry`` default to live snapshots
    (:func:`repro.core.registry.registry_snapshot`); tests inject corrupted
    tables to prove each check fires.
    """
    from repro.core.registry import _normalize, registry_snapshot
    if names is None or registry is None:
        live_names, live_registry = registry_snapshot()
        names = live_names if names is None else names
        registry = live_registry if registry is None else registry
    issues: List[LintIssue] = []

    def err(code: str, subject: str, message: str) -> None:
        issues.append(LintIssue("error", "registry", code, subject, message))

    for alias, target in sorted(names.items()):
        # Follow the resolution chain: alias → id; a healthy table reaches a
        # registered id whose own normalized name maps to itself in one hop.
        seen = []
        current = alias
        while True:
            if current in seen:
                err("ALIAS_CYCLE", alias,
                    f"resolution loops: {' -> '.join(seen + [current])}")
                break
            seen.append(current)
            target_id = names.get(current)
            if target_id is None:
                err("DANGLING_ALIAS", alias,
                    f"chain reaches '{current}', which is not in the alias "
                    f"table")
                break
            if target_id in registry:
                break
            current = _normalize(target_id)

    for arch_id, spec in sorted(registry.items()):
        normalized = _normalize(arch_id)
        if names.get(normalized) != arch_id:
            err("SELF_RESOLUTION", arch_id,
                f"id normalizes to '{normalized}', which resolves to "
                f"{names.get(normalized)!r} instead of itself")
        if not getattr(spec, "is_hlo", False) and spec.parser is None:
            err("NO_PARSER", arch_id, "non-HLO spec has no parser")
    return issues


def lint_arch(spec) -> List[LintIssue]:
    """Lint one registry spec: build its model and cross-check spec ↔ model."""
    issues: List[LintIssue] = []
    model = spec.model_factory()
    if not isinstance(model, MachineModel):
        issues.append(LintIssue(
            "error", spec.id, "MODEL_MISMATCH", "model_factory",
            f"factory produced {type(model).__name__}, not a MachineModel"))
        return issues
    if model.isa != spec.isa:
        issues.append(LintIssue(
            "error", spec.id, "MODEL_MISMATCH", "isa",
            f"spec isa '{spec.isa}' but model isa '{model.isa}'"))
    if model.name != spec.id:
        issues.append(LintIssue(
            "error", spec.id, "MODEL_MISMATCH", "name",
            f"spec id '{spec.id}' but model name '{model.name}'"))
    issues.extend(lint_model(model))
    return issues


def lint_all(arch_ids: Optional[Iterable[str]] = None) -> List[LintIssue]:
    """Registry table + every (requested) asm machine model."""
    from repro.core.registry import asm_arch_ids, get_arch
    issues = lint_registry()
    for arch_id in (arch_ids if arch_ids is not None else asm_arch_ids()):
        issues.extend(lint_arch(get_arch(arch_id)))
    return issues


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.machine.lint",
        description="Statically cross-check the machine DBs and the arch "
                    "registry for consistency.")
    ap.add_argument("archs", nargs="*",
                    help="arch ids/aliases to lint (default: all asm archs)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on warnings too, not only errors")
    args = ap.parse_args(argv)

    issues = lint_all(args.archs or None)
    errors = [i for i in issues if i.severity == "error"]
    warnings_ = [i for i in issues if i.severity == "warning"]
    for issue in issues:
        print(issue)
    from repro.core.registry import asm_arch_ids
    checked = args.archs or asm_arch_ids()
    print(f"lint: {len(checked)} machine DB(s) + registry checked — "
          f"{len(errors)} error(s), {len(warnings_)} warning(s)")
    failed = bool(errors) or (args.strict and bool(warnings_))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
