"""Intel Cascade Lake X (Skylake-SP port model) machine model.

Eight issue ports P0-P7 plus the divider pipe, per the paper's §II: FP
add/mul/FMA on P0/P1 (latency 4, tput 0.5/cy each), integer ALU on P0/P1/P5/P6,
loads on the P2/P3 AGUs (FP-domain load-to-use 6 cy for indexed addressing,
uops.info), store data on P4 with the store AGU spread over P2/P3/P7.  The
store node latency is the SKX store-forward latency (6 cy).  cmp/test+Jcc
macro-fusion is modeled (fused branch issues on P6).

Sources: uops.info SKX tables; Intel SOM; OSACA DB.
"""

from __future__ import annotations

from repro.core.machine.model import DBEntry, MachineModel, uniform

_FP2 = {"P0": 0.5, "P1": 0.5}
_ALU4 = uniform(("P0", "P1", "P5", "P6"))
_LD = {"P2": 0.5, "P3": 0.5}
_ST = {"P4": 1.0, "P2": 1.0 / 3, "P3": 1.0 / 3, "P7": 1.0 / 3}

_DB = {
    # AVX scalar FP: latency 4 on SKX/CLX for add/mul/FMA.
    "vaddsd:fff": DBEntry(latency=4.0, pressure=_FP2),
    "vsubsd:fff": DBEntry(latency=4.0, pressure=_FP2),
    "vmulsd:fff": DBEntry(latency=4.0, pressure=_FP2),
    "addsd:ff": DBEntry(latency=4.0, pressure=_FP2),
    "mulsd:ff": DBEntry(latency=4.0, pressure=_FP2),
    "vfmadd231sd:fff": DBEntry(latency=4.0, pressure=_FP2),
    "vfmadd213sd:fff": DBEntry(latency=4.0, pressure=_FP2),
    "vfmadd132sd:fff": DBEntry(latency=4.0, pressure=_FP2),
    "vdivsd:fff": DBEntry(latency=14.0, pressure={"P0": 1.0, "DIV": 4.0}),
    # Moves/loads/stores.  Load-to-use 6 cy (FP domain, indexed addressing);
    # store node latency = store-forward latency 6 cy.
    "movsd:mf": DBEntry(latency=6.0, pressure=_LD),
    "vmovsd:mf": DBEntry(latency=6.0, pressure=_LD),
    "movsd:fm": DBEntry(latency=6.0, pressure=_ST),
    "vmovsd:fm": DBEntry(latency=6.0, pressure=_ST),
    "movq:mr": DBEntry(latency=5.0, pressure=_LD),
    "movq:rm": DBEntry(latency=6.0, pressure=_ST),
    "movsd:ff": DBEntry(latency=1.0, pressure=_FP2),
    "vmovsd:ff": DBEntry(latency=1.0, pressure=_FP2),
    "movq:rr": DBEntry(latency=1.0, pressure=_ALU4),
    "movl:rr": DBEntry(latency=1.0, pressure=_ALU4),
    "movq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    "movl:ir": DBEntry(latency=1.0, pressure=_ALU4),
    # Integer ALU.
    "addq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    "addq:rr": DBEntry(latency=1.0, pressure=_ALU4),
    "subq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    "incq:r": DBEntry(latency=1.0, pressure=_ALU4),
    "leaq:mr": DBEntry(latency=1.0, pressure={"P1": 0.5, "P5": 0.5}),
    "cmpq:rr": DBEntry(latency=1.0, pressure=_ALU4),
    "cmpq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    "testq:rr": DBEntry(latency=1.0, pressure=_ALU4),
    # Branches (unfused; the fused path is modeled via macro_fusion).
    "jne": DBEntry(latency=1.0, pressure={"P6": 1.0}),
    "je": DBEntry(latency=1.0, pressure={"P6": 1.0}),
    "jb": DBEntry(latency=1.0, pressure={"P6": 1.0}),
    "jmp": DBEntry(latency=1.0, pressure={"P6": 1.0}),
    "nop": DBEntry(latency=0.0, pressure={}),
}


def cascade_lake() -> MachineModel:
    return MachineModel(
        name="csx",
        isa="x86",
        ports=("P0", "P1", "P2", "P3", "P4", "P5", "P6", "P7", "DIV"),
        db=dict(_DB),
        load_entry=DBEntry(latency=6.0, pressure=_LD, note="split load µ-op"),
        store_entry=DBEntry(latency=6.0, pressure=_ST, note="split store µ-op"),
        macro_fusion=True,
        fused_branch_pressure={"P6": 1.0},
        frequency_ghz=2.5,
    )
