"""Intel Cascade Lake X (Skylake-SP port model) machine model.

Eight issue ports P0-P7 plus the divider pipe, per the paper's §II: FP
add/mul/FMA on P0/P1 (latency 4, tput 0.5/cy each), integer ALU on P0/P1/P5/P6,
loads on the P2/P3 AGUs (FP-domain load-to-use 6 cy for indexed addressing,
uops.info), store data on P4 with the store AGU spread over P2/P3/P7.  The
store node latency is the SKX store-forward latency (6 cy).  cmp/test+Jcc
macro-fusion is modeled (fused branch issues on P6).

Entries carry µ-ops with *eligible port sets* (``uops_entry``): one FP µ-op
that may issue on P0 or P1, an ALU µ-op on any of P0/P1/P5/P6, a store split
into its data µ-op (P4) plus its AGU µ-op (P2/P3/P7), and so on.  The derived
``pressure`` keeps the paper's uniform split bit-identical; the min-max
scheduler uses the port sets directly.

Sources: uops.info SKX tables; Intel SOM; OSACA DB.
"""

from __future__ import annotations

from repro.core.machine.model import MachineModel, uops_entry
from repro.core.machine.window import WindowParams

_FP2 = [(1.0, ("P0", "P1"))]
_ALU4 = [(1.0, ("P0", "P1", "P5", "P6"))]
_LD = [(1.0, ("P2", "P3"))]
_ST = [(1.0, ("P4",)), (1.0, ("P2", "P3", "P7"))]  # store data + store AGU
_LEA = [(1.0, ("P1", "P5"))]
_BR = [(1.0, ("P6",))]

_DB = {
    # AVX scalar FP: latency 4 on SKX/CLX for add/mul/FMA.
    "vaddsd:fff": uops_entry(4.0, _FP2),
    "vsubsd:fff": uops_entry(4.0, _FP2),
    "vmulsd:fff": uops_entry(4.0, _FP2),
    "addsd:ff": uops_entry(4.0, _FP2),
    "mulsd:ff": uops_entry(4.0, _FP2),
    "vfmadd231sd:fff": uops_entry(4.0, _FP2),
    "vfmadd213sd:fff": uops_entry(4.0, _FP2),
    "vfmadd132sd:fff": uops_entry(4.0, _FP2),
    "vdivsd:fff": uops_entry(14.0, [(1.0, ("P0",)), (4.0, ("DIV",))]),
    # Moves/loads/stores.  Load-to-use 6 cy (FP domain, indexed addressing);
    # store node latency = store-forward latency 6 cy.
    "movsd:mf": uops_entry(6.0, _LD),
    "vmovsd:mf": uops_entry(6.0, _LD),
    "movsd:fm": uops_entry(6.0, _ST),
    "vmovsd:fm": uops_entry(6.0, _ST),
    "movq:mr": uops_entry(5.0, _LD),
    "movq:rm": uops_entry(6.0, _ST),
    "movsd:ff": uops_entry(1.0, _FP2),
    "vmovsd:ff": uops_entry(1.0, _FP2),
    "movq:rr": uops_entry(1.0, _ALU4),
    "movl:rr": uops_entry(1.0, _ALU4),
    "movq:ir": uops_entry(1.0, _ALU4),
    "movl:ir": uops_entry(1.0, _ALU4),
    # Integer ALU.
    "addq:ir": uops_entry(1.0, _ALU4),
    "addq:rr": uops_entry(1.0, _ALU4),
    "subq:ir": uops_entry(1.0, _ALU4),
    "incq:r": uops_entry(1.0, _ALU4),
    "leaq:mr": uops_entry(1.0, _LEA),
    "cmpq:rr": uops_entry(1.0, _ALU4),
    "cmpq:ir": uops_entry(1.0, _ALU4),
    "testq:rr": uops_entry(1.0, _ALU4),
    # Branches (unfused; the fused path is modeled via macro_fusion).
    "jne": uops_entry(1.0, _BR),
    "je": uops_entry(1.0, _BR),
    "jb": uops_entry(1.0, _BR),
    "jmp": uops_entry(1.0, _BR),
    "nop": uops_entry(0.0, []),
}


def cascade_lake() -> MachineModel:
    return MachineModel(
        name="csx",
        isa="x86",
        ports=("P0", "P1", "P2", "P3", "P4", "P5", "P6", "P7", "DIV"),
        db=dict(_DB),
        load_entry=uops_entry(6.0, _LD, note="split load µ-op"),
        store_entry=uops_entry(6.0, _ST, note="split store µ-op"),
        macro_fusion=True,
        fused_branch_pressure={"P6": 1.0},
        frequency_ghz=2.5,
        # Skylake-SP class window (Intel SOG): 4-wide rename/retire,
        # 224-entry ROB, 97-entry unified RS, 56-entry store queue.
        window=WindowParams(issue_width=4, rob_size=224, sched_size=97,
                            lsq_size=56, retire_width=4).validate(),
    )
