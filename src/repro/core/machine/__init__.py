from repro.core.machine.model import (DBEntry, MachineModel, pressure_uops,
                                      uniform, uops_entry)
from repro.core.machine.window import WindowParams
from repro.core.machine.csx import cascade_lake
from repro.core.machine.n1 import neoverse_n1
from repro.core.machine.tx2 import thunderx2
from repro.core.machine.zen import zen
from repro.core.machine.zen2 import zen2

__all__ = ["DBEntry", "MachineModel", "WindowParams", "pressure_uops",
           "uniform", "uops_entry", "cascade_lake", "neoverse_n1",
           "thunderx2", "zen", "zen2"]
