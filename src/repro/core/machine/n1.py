"""Arm Neoverse N1 (AWS Graviton2) machine model.

From the Arm Neoverse N1 Software Optimization Guide: two FP/ASIMD pipes
(V0/V1), FADD latency 2, FMUL latency 3, FMADD 4; three integer ALUs (one
branch+ALU); two load/store pipes, load-to-use 4, store-forward 4.
Demonstrates the declarative machine-model claim on a post-paper core.

Entries carry µ-ops with *eligible port sets* (``uops_entry``); the derived
``pressure`` keeps the uniform split bit-identical.
"""

from __future__ import annotations

from repro.core.machine.model import MachineModel, uops_entry
from repro.core.machine.window import WindowParams

_FP2 = [(1.0, ("V0", "V1"))]
_ALU3 = [(1.0, ("I0", "I1", "I2"))]
_LD = [(1.0, ("L0", "L1"))]
_ST = [(1.0, ("L0", "L1")), (1.0, ("SD",))]  # store AGU + store data
_BR = [(1.0, ("B",))]

_DB = {
    "fadd:fff": uops_entry(2.0, _FP2),
    "fsub:fff": uops_entry(2.0, _FP2),
    "fmul:fff": uops_entry(3.0, _FP2),
    "fmadd:ffff": uops_entry(4.0, _FP2),
    "fmov:ff": uops_entry(1.0, _FP2),
    "fdiv:fff": uops_entry(15.0, [(1.0, ("V0",)), (7.0, ("DIV",))]),
    "ldr:fm": uops_entry(4.0, _LD),
    "ldr:rm": uops_entry(4.0, _LD),
    "ldp:ffm": uops_entry(4.0, _LD),
    "str:fm": uops_entry(4.0, _ST),
    "str:rm": uops_entry(4.0, _ST),
    "add:rri": uops_entry(1.0, _ALU3),
    "add:rrr": uops_entry(1.0, _ALU3),
    "sub:rri": uops_entry(1.0, _ALU3),
    "subs:rri": uops_entry(1.0, _ALU3),
    "adds:rri": uops_entry(1.0, _ALU3),
    "mov:rr": uops_entry(1.0, _ALU3),
    "mov:ri": uops_entry(1.0, _ALU3),
    "cmp:rr": uops_entry(1.0, _ALU3),
    "cmp:ri": uops_entry(1.0, _ALU3),
    "eor:rrr": uops_entry(1.0, _ALU3),
    "b": uops_entry(1.0, _BR),
    "bne": uops_entry(1.0, _BR),
    "beq": uops_entry(1.0, _BR),
    "cbnz": uops_entry(1.0, _BR),
    "nop": uops_entry(0.0, []),
}


def neoverse_n1() -> MachineModel:
    return MachineModel(
        name="n1",
        isa="aarch64",
        ports=("I0", "I1", "I2", "V0", "V1", "L0", "L1", "SD", "DIV", "B"),
        db=dict(_DB),
        load_entry=uops_entry(4.0, _LD, note="split load µ-op"),
        store_entry=uops_entry(4.0, _ST, note="split store µ-op"),
        macro_fusion=False,
        frequency_ghz=2.5,
        # Neoverse N1 SOG: 4-wide front end, 8-wide retire, 128-entry ROB,
        # distributed issue queues totalling ~64, 46-entry load queue side.
        window=WindowParams(issue_width=4, rob_size=128, sched_size=64,
                            lsq_size=46, retire_width=8).validate(),
    )
