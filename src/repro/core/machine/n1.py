"""Arm Neoverse N1 (AWS Graviton2) machine model.

From the Arm Neoverse N1 Software Optimization Guide: two FP/ASIMD pipes
(V0/V1), FADD latency 2, FMUL latency 3, FMADD 4; three integer ALUs (one
branch+ALU); two load/store pipes, load-to-use 4, store-forward 4.
Demonstrates the declarative machine-model claim on a post-paper core.
"""

from __future__ import annotations

from repro.core.machine.model import DBEntry, MachineModel, uniform

_FP2 = {"V0": 0.5, "V1": 0.5}
_ALU3 = uniform(("I0", "I1", "I2"))
_LD = {"L0": 0.5, "L1": 0.5}
_ST = {"L0": 0.5, "L1": 0.5, "SD": 1.0}

_DB = {
    "fadd:fff": DBEntry(latency=2.0, pressure=_FP2),
    "fsub:fff": DBEntry(latency=2.0, pressure=_FP2),
    "fmul:fff": DBEntry(latency=3.0, pressure=_FP2),
    "fmadd:ffff": DBEntry(latency=4.0, pressure=_FP2),
    "fmov:ff": DBEntry(latency=1.0, pressure=_FP2),
    "fdiv:fff": DBEntry(latency=15.0, pressure={"V0": 1.0, "DIV": 7.0}),
    "ldr:fm": DBEntry(latency=4.0, pressure=_LD),
    "ldr:rm": DBEntry(latency=4.0, pressure=_LD),
    "ldp:ffm": DBEntry(latency=4.0, pressure=_LD),
    "str:fm": DBEntry(latency=4.0, pressure=_ST),
    "str:rm": DBEntry(latency=4.0, pressure=_ST),
    "add:rri": DBEntry(latency=1.0, pressure=_ALU3),
    "add:rrr": DBEntry(latency=1.0, pressure=_ALU3),
    "sub:rri": DBEntry(latency=1.0, pressure=_ALU3),
    "subs:rri": DBEntry(latency=1.0, pressure=_ALU3),
    "adds:rri": DBEntry(latency=1.0, pressure=_ALU3),
    "mov:rr": DBEntry(latency=1.0, pressure=_ALU3),
    "mov:ri": DBEntry(latency=1.0, pressure=_ALU3),
    "cmp:rr": DBEntry(latency=1.0, pressure=_ALU3),
    "cmp:ri": DBEntry(latency=1.0, pressure=_ALU3),
    "eor:rrr": DBEntry(latency=1.0, pressure=_ALU3),
    "b": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "bne": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "beq": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "cbnz": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "nop": DBEntry(latency=0.0, pressure={}),
}


def neoverse_n1() -> MachineModel:
    return MachineModel(
        name="n1",
        isa="aarch64",
        ports=("I0", "I1", "I2", "V0", "V1", "L0", "L1", "SD", "DIV", "B"),
        db=dict(_DB),
        load_entry=DBEntry(latency=4.0, pressure=_LD, note="split load µ-op"),
        store_entry=DBEntry(latency=4.0, pressure=_ST, note="split store µ-op"),
        macro_fusion=False,
        frequency_ghz=2.5,
    )
