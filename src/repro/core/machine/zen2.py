"""AMD Zen 2 machine model (the paper's §IV-B planned target).

Zen 2 vs Zen 1 (Agner Fog's tables / AMD SOG): 256-bit FP datapaths, FADD
latency 3 on FP2/FP3, FMUL/FMA latency 3 on FP0/FP1 (down from 4/5), three
AGUs (two loads + one store per cycle), FP load-to-use 7, store-forward 4.
"""

from __future__ import annotations

from repro.core.machine.model import DBEntry, MachineModel, uniform

_FADD = {"FP2": 0.5, "FP3": 0.5}
_FMUL = {"FP0": 0.5, "FP1": 0.5}
_ALU4 = uniform(("ALU0", "ALU1", "ALU2", "ALU3"))
_LD = {"AGU0": 0.5, "AGU1": 0.5}
_ST = {"AGU2": 1.0, "SD": 1.0}

_DB = {
    "vaddsd:fff": DBEntry(latency=3.0, pressure=_FADD),
    "vsubsd:fff": DBEntry(latency=3.0, pressure=_FADD),
    "vmulsd:fff": DBEntry(latency=3.0, pressure=_FMUL),
    "vfmadd231sd:fff": DBEntry(latency=5.0, pressure=_FMUL),
    "vfmadd213sd:fff": DBEntry(latency=5.0, pressure=_FMUL),
    "vaddpd:fff": DBEntry(latency=3.0, pressure=_FADD),
    "vmulpd:fff": DBEntry(latency=3.0, pressure=_FMUL),
    "vfmadd231pd:fff": DBEntry(latency=5.0, pressure=_FMUL),
    "vdivsd:fff": DBEntry(latency=13.0, pressure={"FP3": 1.0, "DIV": 4.0}),
    "movsd:mf": DBEntry(latency=7.0, pressure=_LD),
    "vmovsd:mf": DBEntry(latency=7.0, pressure=_LD),
    "vmovupd:mf": DBEntry(latency=7.0, pressure=_LD),
    "movsd:fm": DBEntry(latency=4.0, pressure=_ST),
    "vmovsd:fm": DBEntry(latency=4.0, pressure=_ST),
    "vmovupd:fm": DBEntry(latency=4.0, pressure=_ST),
    "movq:mr": DBEntry(latency=4.0, pressure=_LD),
    "movq:rm": DBEntry(latency=4.0, pressure=_ST),
    "movsd:ff": DBEntry(latency=1.0, pressure={"FP0": 0.25, "FP1": 0.25,
                                               "FP2": 0.25, "FP3": 0.25}),
    "movq:rr": DBEntry(latency=1.0, pressure=_ALU4),
    "addq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    "addq:rr": DBEntry(latency=1.0, pressure=_ALU4),
    "subq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    "leaq:mr": DBEntry(latency=1.0, pressure=_ALU4),
    "cmpq:rr": DBEntry(latency=1.0, pressure=_ALU4),
    "cmpq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    "jne": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "je": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "jmp": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "nop": DBEntry(latency=0.0, pressure={}),
}


def zen2() -> MachineModel:
    return MachineModel(
        name="zen2",
        isa="x86",
        ports=("ALU0", "ALU1", "ALU2", "ALU3", "AGU0", "AGU1", "AGU2",
               "FP0", "FP1", "FP2", "FP3", "SD", "DIV", "B"),
        db=dict(_DB),
        load_entry=DBEntry(latency=7.0, pressure=_LD, note="split load µ-op"),
        store_entry=DBEntry(latency=4.0, pressure=_ST, note="split store µ-op"),
        macro_fusion=True,
        fused_branch_pressure={"B": 1.0},
        frequency_ghz=3.4,
    )
