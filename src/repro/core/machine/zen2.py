"""AMD Zen 2 machine model (the paper's §IV-B planned target).

Zen 2 vs Zen 1 (Agner Fog's tables / AMD SOG): 256-bit FP datapaths, FADD
latency 3 on FP2/FP3, FMUL/FMA latency 3 on FP0/FP1 (down from 4/5), three
AGUs (two loads + one store per cycle), FP load-to-use 7, store-forward 4.

Entries carry µ-ops with *eligible port sets* (``uops_entry``); the derived
``pressure`` keeps the uniform split bit-identical.
"""

from __future__ import annotations

from repro.core.machine.model import MachineModel, uops_entry
from repro.core.machine.window import WindowParams

_FADD = [(1.0, ("FP2", "FP3"))]
_FMUL = [(1.0, ("FP0", "FP1"))]
_FMOV = [(1.0, ("FP0", "FP1", "FP2", "FP3"))]
_ALU4 = [(1.0, ("ALU0", "ALU1", "ALU2", "ALU3"))]
_LD = [(1.0, ("AGU0", "AGU1"))]
_ST = [(1.0, ("AGU2",)), (1.0, ("SD",))]  # dedicated store AGU + store data
_BR = [(1.0, ("B",))]

_DB = {
    "vaddsd:fff": uops_entry(3.0, _FADD),
    "vsubsd:fff": uops_entry(3.0, _FADD),
    "vmulsd:fff": uops_entry(3.0, _FMUL),
    "vfmadd231sd:fff": uops_entry(5.0, _FMUL),
    "vfmadd213sd:fff": uops_entry(5.0, _FMUL),
    "vaddpd:fff": uops_entry(3.0, _FADD),
    "vmulpd:fff": uops_entry(3.0, _FMUL),
    "vfmadd231pd:fff": uops_entry(5.0, _FMUL),
    "vdivsd:fff": uops_entry(13.0, [(1.0, ("FP3",)), (4.0, ("DIV",))]),
    "movsd:mf": uops_entry(7.0, _LD),
    "vmovsd:mf": uops_entry(7.0, _LD),
    "vmovupd:mf": uops_entry(7.0, _LD),
    "movsd:fm": uops_entry(4.0, _ST),
    "vmovsd:fm": uops_entry(4.0, _ST),
    "vmovupd:fm": uops_entry(4.0, _ST),
    "movq:mr": uops_entry(4.0, _LD),
    "movq:rm": uops_entry(4.0, _ST),
    "movsd:ff": uops_entry(1.0, _FMOV),
    "movq:rr": uops_entry(1.0, _ALU4),
    "addq:ir": uops_entry(1.0, _ALU4),
    "addq:rr": uops_entry(1.0, _ALU4),
    "subq:ir": uops_entry(1.0, _ALU4),
    "leaq:mr": uops_entry(1.0, _ALU4),
    "cmpq:rr": uops_entry(1.0, _ALU4),
    "cmpq:ir": uops_entry(1.0, _ALU4),
    "jne": uops_entry(1.0, _BR),
    "je": uops_entry(1.0, _BR),
    "jmp": uops_entry(1.0, _BR),
    "nop": uops_entry(0.0, []),
}


def zen2() -> MachineModel:
    return MachineModel(
        name="zen2",
        isa="x86",
        ports=("ALU0", "ALU1", "ALU2", "ALU3", "AGU0", "AGU1", "AGU2",
               "FP0", "FP1", "FP2", "FP3", "SD", "DIV", "B"),
        db=dict(_DB),
        load_entry=uops_entry(7.0, _LD, note="split load µ-op"),
        store_entry=uops_entry(4.0, _ST, note="split store µ-op"),
        macro_fusion=True,
        fused_branch_pressure={"B": 1.0},
        frequency_ghz=3.4,
        # Zen 2: 6-wide dispatch, 8-wide retire, 224-entry ROB, ~92
        # scheduler entries, 48-entry store queue.
        window=WindowParams(issue_width=6, rob_size=224, sched_size=92,
                            lsq_size=48, retire_width=8).validate(),
    )
