"""Marvell ThunderX2 (Vulcan) machine model.

Port layout follows the paper's Table II: six numbered ports P0-P5 plus a
branch unit.  P0/P1 carry the FP pipes (FP latency 6 cy — the documented
Vulcan FP add/mul latency), P0-P2 are the integer ALUs, P3/P4 are the
load/store AGUs (load-to-use 4 cy), and stores additionally occupy the store
buffer port P5 for one cycle.  Values from the Vulcan micro-architecture
disclosures and the OSACA instruction database (semi-automatic ibench runs in
the paper's artifact).

Entries carry µ-ops with *eligible port sets* (``uops_entry``): the derived
``pressure`` keeps the paper's uniform split bit-identical (Table II), while
the min-max scheduler may e.g. push all integer ALU work onto P2 when P0/P1
are saturated by FP.
"""

from __future__ import annotations

from repro.core.machine.model import MachineModel, uops_entry
from repro.core.machine.window import WindowParams

_FP2 = [(1.0, ("P0", "P1"))]
_ALU3 = [(1.0, ("P0", "P1", "P2"))]
_LD = [(1.0, ("P3", "P4"))]
_ST = [(1.0, ("P3", "P4")), (1.0, ("P5",))]  # store AGU + store buffer
_BR = [(1.0, ("B",))]

_DB = {
    # Scalar FP (d-form NEON scalar): latency 6, tput 0.5/port over P0,P1.
    "fadd:fff": uops_entry(6.0, _FP2),
    "fsub:fff": uops_entry(6.0, _FP2),
    "fmul:fff": uops_entry(6.0, _FP2),
    "fmadd:ffff": uops_entry(6.0, _FP2),
    "fmov:ff": uops_entry(1.0, _FP2),
    "fdiv:fff": uops_entry(23.0, [(1.0, ("P0",)), (16.0, ("DIV",))]),
    # Loads/stores: load-to-use 4 cy, AGUs on P3/P4; store data port P5.
    "ldr:fm": uops_entry(4.0, _LD),
    "ldr:rm": uops_entry(4.0, _LD),
    "ldp:ffm": uops_entry(4.0, _LD),
    "str:fm": uops_entry(4.0, _ST),
    "str:rm": uops_entry(4.0, _ST),
    # Integer ALU.
    "add:rri": uops_entry(1.0, _ALU3),
    "add:rrr": uops_entry(1.0, _ALU3),
    "sub:rri": uops_entry(1.0, _ALU3),
    "sub:rrr": uops_entry(1.0, _ALU3),
    "mov:rr": uops_entry(1.0, _FP2),
    "mov:ri": uops_entry(1.0, _FP2),
    "cmp:rr": uops_entry(1.0, _ALU3),
    "cmp:ri": uops_entry(1.0, _ALU3),
    "eor:rrr": uops_entry(1.0, _ALU3),
    "orr:rrr": uops_entry(1.0, _ALU3),
    "and:rrr": uops_entry(1.0, _ALU3),
    "lsl:rri": uops_entry(1.0, _ALU3),
    "madd:rrrr": uops_entry(3.0, [(1.0, ("P0",))]),
    # Branch unit.
    "b": uops_entry(1.0, _BR),
    "bne": uops_entry(1.0, _BR),
    "beq": uops_entry(1.0, _BR),
    "cbnz": uops_entry(1.0, _BR),
    "nop": uops_entry(0.0, []),
}


def thunderx2() -> MachineModel:
    return MachineModel(
        name="tx2",
        isa="aarch64",
        ports=("P0", "P1", "P2", "P3", "P4", "P5", "DIV", "B"),
        db=dict(_DB),
        load_entry=uops_entry(4.0, _LD, note="split load µ-op"),
        store_entry=uops_entry(4.0, _ST, note="split store µ-op"),
        macro_fusion=False,
        frequency_ghz=2.2,
        # Vulcan-class window: 4-wide dispatch/retire, 180-entry ROB,
        # 60 scheduler entries across the issue queues, 36-entry LSQ side.
        window=WindowParams(issue_width=4, rob_size=180, sched_size=60,
                            lsq_size=36, retire_width=4).validate(),
    )
