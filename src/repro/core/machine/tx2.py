"""Marvell ThunderX2 (Vulcan) machine model.

Port layout follows the paper's Table II: six numbered ports P0-P5 plus a
branch unit.  P0/P1 carry the FP pipes (FP latency 6 cy — the documented
Vulcan FP add/mul latency), P0-P2 are the integer ALUs, P3/P4 are the
load/store AGUs (load-to-use 4 cy), and stores additionally occupy the store
buffer port P5 for one cycle.  Values from the Vulcan micro-architecture
disclosures and the OSACA instruction database (semi-automatic ibench runs in
the paper's artifact).
"""

from __future__ import annotations

from repro.core.machine.model import DBEntry, MachineModel, uniform

_FP2 = {"P0": 0.5, "P1": 0.5}
_ALU3 = uniform(("P0", "P1", "P2"))
_LD = {"P3": 0.5, "P4": 0.5}
_ST = {"P3": 0.5, "P4": 0.5, "P5": 1.0}

_DB = {
    # Scalar FP (d-form NEON scalar): latency 6, tput 0.5/port over P0,P1.
    "fadd:fff": DBEntry(latency=6.0, pressure=_FP2),
    "fsub:fff": DBEntry(latency=6.0, pressure=_FP2),
    "fmul:fff": DBEntry(latency=6.0, pressure=_FP2),
    "fmadd:ffff": DBEntry(latency=6.0, pressure=_FP2),
    "fmov:ff": DBEntry(latency=1.0, pressure=_FP2),
    "fdiv:fff": DBEntry(latency=23.0, pressure={"P0": 1.0, "DIV": 16.0}),
    # Loads/stores: load-to-use 4 cy, AGUs on P3/P4; store data port P5.
    "ldr:fm": DBEntry(latency=4.0, pressure=_LD),
    "ldr:rm": DBEntry(latency=4.0, pressure=_LD),
    "ldp:ffm": DBEntry(latency=4.0, pressure=_LD),
    "str:fm": DBEntry(latency=4.0, pressure=_ST),
    "str:rm": DBEntry(latency=4.0, pressure=_ST),
    # Integer ALU.
    "add:rri": DBEntry(latency=1.0, pressure=_ALU3),
    "add:rrr": DBEntry(latency=1.0, pressure=_ALU3),
    "sub:rri": DBEntry(latency=1.0, pressure=_ALU3),
    "sub:rrr": DBEntry(latency=1.0, pressure=_ALU3),
    "mov:rr": DBEntry(latency=1.0, pressure={"P0": 0.5, "P1": 0.5}),
    "mov:ri": DBEntry(latency=1.0, pressure={"P0": 0.5, "P1": 0.5}),
    "cmp:rr": DBEntry(latency=1.0, pressure=_ALU3),
    "cmp:ri": DBEntry(latency=1.0, pressure=_ALU3),
    "eor:rrr": DBEntry(latency=1.0, pressure=_ALU3),
    "orr:rrr": DBEntry(latency=1.0, pressure=_ALU3),
    "and:rrr": DBEntry(latency=1.0, pressure=_ALU3),
    "lsl:rri": DBEntry(latency=1.0, pressure=_ALU3),
    "madd:rrrr": DBEntry(latency=3.0, pressure={"P0": 1.0}),
    # Branch unit.
    "b": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "bne": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "beq": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "cbnz": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "nop": DBEntry(latency=0.0, pressure={}),
}


def thunderx2() -> MachineModel:
    return MachineModel(
        name="tx2",
        isa="aarch64",
        ports=("P0", "P1", "P2", "P3", "P4", "P5", "DIV", "B"),
        db=dict(_DB),
        load_entry=DBEntry(latency=4.0, pressure=_LD, note="split load µ-op"),
        store_entry=DBEntry(latency=4.0, pressure=_ST, note="split store µ-op"),
        macro_fusion=False,
        frequency_ghz=2.2,
    )
