"""Out-of-order *window* parameters for the point-prediction simulator.

The paper's port model is window-less: throughput assumes an infinite
scheduling window, the critical path assumes no resource limits at all.
Real cores sit between the two because the instruction window is finite.
:class:`WindowParams` captures the handful of capacities that bound it:

``issue_width``
    µ-ops renamed/dispatched into the backend per cycle (frontend width).
``rob_size``
    re-order buffer entries; an instruction holds one from dispatch until
    in-order retirement.
``sched_size``
    unified scheduler (reservation-station) entries; held from dispatch
    until the µ-op issues to a port.
``lsq_size``
    load/store-queue depth; loads and stores each hold an entry from
    dispatch until retirement (modeled as two queues of this depth).
``retire_width``
    µ-ops retired in order per cycle.

Values in the per-arch machine DBs are modeling parameters on the same
footing as the latency/pressure tables: they follow the vendor software
optimization guides at the resolution the simulator needs, not RTL truth.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class WindowParams:
    issue_width: int
    rob_size: int
    sched_size: int
    lsq_size: int
    retire_width: int

    def validate(self) -> "WindowParams":
        """Enforce the sanity bounds every shipped arch must satisfy."""
        for name in ("issue_width", "rob_size", "sched_size", "lsq_size",
                     "retire_width"):
            value = getattr(self, name)
            if not isinstance(value, int) or value <= 0:
                raise ValueError(f"window.{name} must be a positive int, "
                                 f"got {value!r}")
        if not self.issue_width <= self.retire_width <= self.rob_size:
            raise ValueError(
                f"window requires issue_width <= retire_width <= rob_size, "
                f"got {self.issue_width} / {self.retire_width} / {self.rob_size}")
        if not self.lsq_size <= self.sched_size <= self.rob_size:
            raise ValueError(
                f"window requires lsq_size <= sched_size <= rob_size, "
                f"got {self.lsq_size} / {self.sched_size} / {self.rob_size}")
        return self

    def to_dict(self) -> Dict[str, int]:
        return {
            "issue_width": self.issue_width,
            "rob_size": self.rob_size,
            "sched_size": self.sched_size,
            "lsq_size": self.lsq_size,
            "retire_width": self.retire_width,
        }

    @classmethod
    def from_dict(cls, data: Dict[str, int]) -> "WindowParams":
        return cls(**{k: int(data[k]) for k in (
            "issue_width", "rob_size", "sched_size", "lsq_size",
            "retire_width")})
