"""AMD Zen (EPYC 7451, Zen 1) machine model.

Zen 1 back end: four integer ALUs, two AGUs shared between loads and stores,
four FP pipes (FADD on FP2/FP3 latency 3, FMUL on FP0/FP1 latency 4 — Agner
Fog's Zen tables), a store-data path (SD), and a branch unit.  FP-domain
load-to-use is 7 cy; the store node latency is the Zen store-forward latency
(4 cy).  cmp+Jcc fusion is supported on Zen.
"""

from __future__ import annotations

from repro.core.machine.model import DBEntry, MachineModel, uniform

_FADD = {"FP2": 0.5, "FP3": 0.5}
_FMUL = {"FP0": 0.5, "FP1": 0.5}
_ALU4 = uniform(("ALU0", "ALU1", "ALU2", "ALU3"))
_AGU = {"AGU0": 0.5, "AGU1": 0.5}
_ST = {"AGU0": 0.5, "AGU1": 0.5, "SD": 1.0}

_DB = {
    "vaddsd:fff": DBEntry(latency=3.0, pressure=_FADD),
    "vsubsd:fff": DBEntry(latency=3.0, pressure=_FADD),
    "vmulsd:fff": DBEntry(latency=4.0, pressure=_FMUL),
    "addsd:ff": DBEntry(latency=3.0, pressure=_FADD),
    "mulsd:ff": DBEntry(latency=4.0, pressure=_FMUL),
    "vfmadd231sd:fff": DBEntry(latency=5.0, pressure=_FMUL),
    "vfmadd213sd:fff": DBEntry(latency=5.0, pressure=_FMUL),
    "vdivsd:fff": DBEntry(latency=13.0, pressure={"FP3": 1.0, "DIV": 4.0}),
    # Memory.
    "movsd:mf": DBEntry(latency=7.0, pressure=_AGU),
    "vmovsd:mf": DBEntry(latency=7.0, pressure=_AGU),
    "movsd:fm": DBEntry(latency=4.0, pressure=_ST),
    "vmovsd:fm": DBEntry(latency=4.0, pressure=_ST),
    "movq:mr": DBEntry(latency=4.0, pressure=_AGU),
    "movq:rm": DBEntry(latency=4.0, pressure=_ST),
    "movsd:ff": DBEntry(latency=1.0, pressure={"FP0": 0.25, "FP1": 0.25, "FP2": 0.25, "FP3": 0.25}),
    "movq:rr": DBEntry(latency=1.0, pressure=_ALU4),
    "movq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    # Integer ALU.
    "addq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    "addq:rr": DBEntry(latency=1.0, pressure=_ALU4),
    "subq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    "leaq:mr": DBEntry(latency=1.0, pressure=_ALU4),
    "cmpq:rr": DBEntry(latency=1.0, pressure=_ALU4),
    "cmpq:ir": DBEntry(latency=1.0, pressure=_ALU4),
    "jne": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "je": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "jmp": DBEntry(latency=1.0, pressure={"B": 1.0}),
    "nop": DBEntry(latency=0.0, pressure={}),
}


def zen() -> MachineModel:
    return MachineModel(
        name="zen",
        isa="x86",
        ports=("ALU0", "ALU1", "ALU2", "ALU3", "AGU0", "AGU1",
               "FP0", "FP1", "FP2", "FP3", "SD", "DIV", "B"),
        db=dict(_DB),
        load_entry=DBEntry(latency=7.0, pressure=_AGU, note="split load µ-op"),
        store_entry=DBEntry(latency=4.0, pressure=_ST, note="split store µ-op"),
        macro_fusion=True,
        fused_branch_pressure={"B": 1.0},
        frequency_ghz=2.3,
    )
