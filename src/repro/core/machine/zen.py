"""AMD Zen (EPYC 7451, Zen 1) machine model.

Zen 1 back end: four integer ALUs, two AGUs shared between loads and stores,
four FP pipes (FADD on FP2/FP3 latency 3, FMUL on FP0/FP1 latency 4 — Agner
Fog's Zen tables), a store-data path (SD), and a branch unit.  FP-domain
load-to-use is 7 cy; the store node latency is the Zen store-forward latency
(4 cy).  cmp+Jcc fusion is supported on Zen.

Entries carry µ-ops with *eligible port sets* (``uops_entry``); the derived
``pressure`` keeps the uniform split bit-identical while the min-max
scheduler assigns loads/stores across the shared AGU pair optimally.
"""

from __future__ import annotations

from repro.core.machine.model import MachineModel, uops_entry
from repro.core.machine.window import WindowParams

_FADD = [(1.0, ("FP2", "FP3"))]
_FMUL = [(1.0, ("FP0", "FP1"))]
_FMOV = [(1.0, ("FP0", "FP1", "FP2", "FP3"))]
_ALU4 = [(1.0, ("ALU0", "ALU1", "ALU2", "ALU3"))]
_AGU = [(1.0, ("AGU0", "AGU1"))]
_ST = [(1.0, ("AGU0", "AGU1")), (1.0, ("SD",))]  # store AGU + store data
_BR = [(1.0, ("B",))]

_DB = {
    "vaddsd:fff": uops_entry(3.0, _FADD),
    "vsubsd:fff": uops_entry(3.0, _FADD),
    "vmulsd:fff": uops_entry(4.0, _FMUL),
    "addsd:ff": uops_entry(3.0, _FADD),
    "mulsd:ff": uops_entry(4.0, _FMUL),
    "vfmadd231sd:fff": uops_entry(5.0, _FMUL),
    "vfmadd213sd:fff": uops_entry(5.0, _FMUL),
    "vdivsd:fff": uops_entry(13.0, [(1.0, ("FP3",)), (4.0, ("DIV",))]),
    # Memory.
    "movsd:mf": uops_entry(7.0, _AGU),
    "vmovsd:mf": uops_entry(7.0, _AGU),
    "movsd:fm": uops_entry(4.0, _ST),
    "vmovsd:fm": uops_entry(4.0, _ST),
    "movq:mr": uops_entry(4.0, _AGU),
    "movq:rm": uops_entry(4.0, _ST),
    "movsd:ff": uops_entry(1.0, _FMOV),
    "movq:rr": uops_entry(1.0, _ALU4),
    "movq:ir": uops_entry(1.0, _ALU4),
    # Integer ALU.
    "addq:ir": uops_entry(1.0, _ALU4),
    "addq:rr": uops_entry(1.0, _ALU4),
    "subq:ir": uops_entry(1.0, _ALU4),
    "leaq:mr": uops_entry(1.0, _ALU4),
    "cmpq:rr": uops_entry(1.0, _ALU4),
    "cmpq:ir": uops_entry(1.0, _ALU4),
    "jne": uops_entry(1.0, _BR),
    "je": uops_entry(1.0, _BR),
    "jmp": uops_entry(1.0, _BR),
    "nop": uops_entry(0.0, []),
}


def zen() -> MachineModel:
    return MachineModel(
        name="zen",
        isa="x86",
        ports=("ALU0", "ALU1", "ALU2", "ALU3", "AGU0", "AGU1",
               "FP0", "FP1", "FP2", "FP3", "SD", "DIV", "B"),
        db=dict(_DB),
        load_entry=uops_entry(7.0, _AGU, note="split load µ-op"),
        store_entry=uops_entry(4.0, _ST, note="split store µ-op"),
        macro_fusion=True,
        fused_branch_pressure={"B": 1.0},
        frequency_ghz=2.3,
        # Zen 1 (AMD SOG 55723): 6-wide dispatch, 8-wide retire, 192-entry
        # retire queue, ~84 scheduler entries (ALU+AGU+FP), 44-entry SQ.
        window=WindowParams(issue_width=6, rob_size=192, sched_size=84,
                            lsq_size=44, retire_width=8).validate(),
    )
