from repro.core.validation.gauss_seidel import (
    GS_CLX_ASM,
    GS_TX2_ASM,
    GS_ZEN_ASM,
    TABLE1,
    table1_row,
)

__all__ = ["GS_CLX_ASM", "GS_TX2_ASM", "GS_ZEN_ASM", "TABLE1", "table1_row"]
