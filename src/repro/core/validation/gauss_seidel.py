"""Gauss-Seidel validation kernels (paper §III-A, Tables I & II).

``GS_TX2_ASM`` is the ThunderX2 assembly transcribed verbatim from the paper's
Table II (gfortran 8.2, -mcpu=thunderx2t99 -funroll-loops -Ofast, 4x unroll).
``GS_CLX_ASM`` / ``GS_ZEN_ASM`` are the corresponding 4x-unrolled scalar
x86 kernels reconstructed per DESIGN.md §2.1: 12 loads, 12 adds + 4 muls,
4 stores, 3 pointer bumps, fused cmp+jne, with the compiler's alternating
re-association of the 4-term stencil sum across unrolled copies
(dep-second / dep-first / dep-second / dep-first).
"""

from __future__ import annotations

from dataclasses import dataclass

GS_TX2_ASM = """
# OSACA-BEGIN
.L20:
    ldr     d31, [x15, x18, lsl 3]
    ldr     d0, [x15, 8]
    mov     x14, x15
    add     x16, x15, 24
    ldr     d2, [x15, x30, lsl 3]
    add     x15, x15, 32
    fadd    d1, d31, d0
    fadd    d3, d1, d30
    fadd    d4, d3, d2
    fmul    d5, d4, d9
    str     d5, [x14], 8
    ldr     d6, [x14, x18, lsl 3]
    ldr     d16, [x14, 8]
    add     x13, x14, 8
    ldr     d7, [x14, x30, lsl 3]
    fadd    d17, d6, d16
    fadd    d18, d17, d5
    fadd    d19, d18, d7
    fmul    d20, d19, d9
    str     d20, [x15, -24]
    ldr     d21, [x13, x18, lsl 3]
    ldr     d23, [x14, 16]
    ldr     d22, [x13, x30, lsl 3]
    fadd    d24, d21, d23
    fadd    d25, d24, d20
    fadd    d26, d25, d22
    fmul    d27, d26, d9
    str     d27, [x14, 8]
    ldr     d30, [x15]
    ldr     d28, [x16, x18, lsl 3]
    ldr     d29, [x16, x30, lsl 3]
    fadd    d31, d28, d30
    fadd    d2, d31, d27
    fadd    d0, d2, d29
    fmul    d30, d0, d9
    str     d30, [x15, -8]
    cmp     x7, x15
    bne     .L20
# OSACA-END
"""

# x86 reconstruction: %rsi = row k-1, %rax = row k (in-place), %rdx = row k+1,
# %xmm9 = 0.25, %xmm0 = loop-carried previous result phi(i-1,k).
# Copies alternate dep-second (prev enters 2nd add) / dep-first (1st add).
GS_CLX_ASM = """
# OSACA-BEGIN
..B2.7:
    movsd     (%rsi,%rbx,8), %xmm1
    movsd     8(%rax,%rbx,8), %xmm2
    movsd     (%rdx,%rbx,8), %xmm3
    vaddsd    %xmm2, %xmm1, %xmm4
    vaddsd    %xmm0, %xmm4, %xmm5
    vaddsd    %xmm3, %xmm5, %xmm6
    vmulsd    %xmm9, %xmm6, %xmm0
    movsd     %xmm0, (%rax,%rbx,8)
    movsd     8(%rsi,%rbx,8), %xmm1
    movsd     16(%rax,%rbx,8), %xmm2
    movsd     8(%rdx,%rbx,8), %xmm3
    vaddsd    %xmm1, %xmm0, %xmm4
    vaddsd    %xmm2, %xmm4, %xmm5
    vaddsd    %xmm3, %xmm5, %xmm6
    vmulsd    %xmm9, %xmm6, %xmm0
    movsd     %xmm0, 8(%rax,%rbx,8)
    movsd     16(%rsi,%rbx,8), %xmm1
    movsd     24(%rax,%rbx,8), %xmm2
    movsd     16(%rdx,%rbx,8), %xmm3
    vaddsd    %xmm2, %xmm1, %xmm4
    vaddsd    %xmm0, %xmm4, %xmm5
    vaddsd    %xmm3, %xmm5, %xmm6
    vmulsd    %xmm9, %xmm6, %xmm0
    movsd     %xmm0, 16(%rax,%rbx,8)
    movsd     24(%rsi,%rbx,8), %xmm1
    movsd     32(%rax,%rbx,8), %xmm2
    movsd     24(%rdx,%rbx,8), %xmm3
    vaddsd    %xmm1, %xmm0, %xmm4
    vaddsd    %xmm2, %xmm4, %xmm5
    vaddsd    %xmm3, %xmm5, %xmm6
    vmulsd    %xmm9, %xmm6, %xmm0
    movsd     %xmm0, 24(%rax,%rbx,8)
    addq      $32, %rsi
    addq      $32, %rax
    addq      $32, %rdx
    cmpq      %r13, %rax
    jne       ..B2.7
# OSACA-END
"""

# Zen: gfortran -mavx2 -mfma -Ofast; same structure, Zen latencies differ.
GS_ZEN_ASM = GS_CLX_ASM.replace("..B2.7", ".L7")


@dataclass(frozen=True)
class Table1Row:
    arch: str
    unroll: int
    measured_mlups: float
    measured_cy_per_it: float
    tp: float
    lcd: float
    cp: float


TABLE1 = {
    "tx2": Table1Row("tx2", 4, 118.9, 18.50, 2.46, 18.00, 25.00),
    "csx": Table1Row("csx", 4, 178.3, 14.02, 2.19, 14.00, 18.00),
    "zen": Table1Row("zen", 4, 194.4, 11.83, 2.00, 11.50, 15.00),
}


def table1_row(arch: str) -> Table1Row:
    return TABLE1[arch]
