"""Critical path over the HLO def-use DAG (paper §II-C on TPU).

Node weights are per-op bottleneck-engine times from the cost model; the
longest path is the serialization bound of the step — what limits runtime
even with infinite parallel resources.  ``while`` ops contribute their body's
critical path times the inferred trip count (the scan-over-layers chain, the
decode loop), which is how the paper's LCD insight shows up at module scale.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.hlo.costs import HLOCostModel
from repro.core.hlo.machine import TPUChip, TPU_V5E
from repro.core.hlo.parser import HLOModule, HLOOp, parse_hlo


@dataclass
class HLOPathNode:
    op_name: str
    opcode: str
    seconds: float


@dataclass
class HLOCriticalPath:
    seconds: float
    path: Tuple[HLOPathNode, ...]

    def top_contributors(self, k: int = 10) -> List[HLOPathNode]:
        return sorted(self.path, key=lambda n: -n.seconds)[:k]

    def render(self) -> str:
        lines = [f"HLO critical path: {self.seconds * 1e3:.3f} ms "
                 f"({len(self.path)} ops)"]
        for node in self.top_contributors(8):
            lines.append(f"  {node.seconds * 1e3:9.4f} ms  {node.opcode:<22} {node.op_name}")
        return "\n".join(lines)


def _computation_cp(
    module: HLOModule, comp_name: str, cost: HLOCostModel,
    memo: Dict[str, Tuple[float, Tuple[HLOPathNode, ...]]],
) -> Tuple[float, Tuple[HLOPathNode, ...]]:
    if comp_name in memo:
        return memo[comp_name]
    memo[comp_name] = (0.0, ())  # cycle guard
    comp = module.computations.get(comp_name)
    if comp is None:
        return 0.0, ()

    index = {op.name: i for i, op in enumerate(comp.ops)}
    n = len(comp.ops)
    dist = [0.0] * n
    parent = [-1] * n

    weights: List[float] = []
    for op in comp.ops:
        if op.opcode == "while":
            trips = cost.while_trip_count(op)
            body = op.body_computation
            body_cp, _ = _computation_cp(module, body, cost, memo) if body else (0.0, ())
            weights.append(trips * body_cp)
        elif op.opcode in ("fusion", "call"):
            inner = max(
                (_computation_cp(module, c, cost, memo)[0]
                 for c in op.called_computations), default=0.0,
            )
            weights.append(max(cost.op_seconds(op, comp), inner))
        else:
            weights.append(cost.op_seconds(op, comp))

    # Same forward sweep as the assembly engine, over resolved predecessor
    # lists (every node may start a path at floor 0: a zero-time pred never
    # becomes a parent, matching the batched sweep's path-through rule).
    preds = [
        [j for operand in op.operands
         if (j := index.get(operand)) is not None and j < i]
        for i, op in enumerate(comp.ops)
    ]
    for i in range(n):
        best, best_p = 0.0, -1
        for j in preds[i]:
            if dist[j] > best:
                best, best_p = dist[j], j
        dist[i] = best + weights[i]
        parent[i] = best_p

    if not comp.ops:
        return 0.0, ()
    end = max(range(n), key=lambda i: dist[i])
    path: List[HLOPathNode] = []
    v = end
    while v != -1:
        op = comp.ops[v]
        path.append(HLOPathNode(op_name=op.name, opcode=op.opcode, seconds=weights[v]))
        v = parent[v]
    path.reverse()
    memo[comp_name] = (dist[end], tuple(path))
    return memo[comp_name]


def hlo_critical_path(
    source, chip: TPUChip = TPU_V5E, default_while_trips: int = 1,
) -> HLOCriticalPath:
    """``source`` is HLO text, a parsed module, or a Compiled object."""
    if hasattr(source, "as_text"):
        source = source.as_text()
    module = source if isinstance(source, HLOModule) else parse_hlo(source)
    cost = HLOCostModel(module, chip, default_while_trips=default_while_trips)
    memo: Dict[str, Tuple[float, Tuple[HLOPathNode, ...]]] = {}
    seconds, path = _computation_cp(module, module.entry_name, cost, memo)
    return HLOCriticalPath(seconds=seconds, path=path)
