"""Parser for post-optimization XLA HLO text (``compiled.as_text()``).

This is the TPU analogue of the assembly front-ends in ``repro.core.isa``:
HLO is the "assembly" XLA schedules onto the chip's engines.  The parser
extracts computations, ops, result shapes, operand def-use links, and the
attributes the analyses need (replica groups, called computations, dot
contraction dims).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)


@dataclass(frozen=True)
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elements(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return int(self.elements * _DTYPE_BYTES.get(self.dtype, 4))


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")


def parse_shapes(text: str) -> Tuple[Shape, ...]:
    """Parse one shape or a tuple of shapes from HLO type syntax."""
    shapes = []
    for m in _SHAPE_RE.finditer(text):
        dtype = m.group(1)
        if dtype not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d != "")
        shapes.append(Shape(dtype=dtype, dims=dims))
    return tuple(shapes)


@dataclass
class HLOOp:
    name: str
    opcode: str
    shapes: Tuple[Shape, ...]
    operands: Tuple[str, ...]
    attrs: str = ""
    is_root: bool = False
    raw: str = ""

    @property
    def result_bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)

    @property
    def is_collective(self) -> bool:
        base = self.opcode.replace("-start", "").replace("-done", "")
        return base in COLLECTIVE_OPS

    @property
    def called_computations(self) -> Tuple[str, ...]:
        names = []
        for key in ("calls=", "to_apply=", "body=", "condition=", "branch_computations="):
            for m in re.finditer(re.escape(key) + r"\{?%?([\w.\-]+)", self.attrs):
                names.append(m.group(1))
        return tuple(names)

    def _attr_computation(self, key: str) -> Optional[str]:
        m = re.search(re.escape(key) + r"%?([\w.\-]+)", self.attrs)
        return m.group(1) if m else None

    @property
    def body_computation(self) -> Optional[str]:
        return self._attr_computation("body=")

    @property
    def condition_computation(self) -> Optional[str]:
        return self._attr_computation("condition=")

    @property
    def known_trip_count(self) -> Optional[int]:
        """XLA-recorded trip count (backend_config) for while ops."""
        m = re.search(r"known_trip_count[^0-9]*(\d+)", self.attrs)
        return int(m.group(1)) if m else None

    def replica_group_size(self, num_partitions: int) -> int:
        """Number of participants per replica group."""
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", self.attrs)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", self.attrs)
        if m:
            return len(m.group(1).split(","))
        return num_partitions

    def dot_contracting(self, lhs_shape: Optional[Shape]) -> int:
        """Product of the LHS contracting dims of a dot (for FLOP counts)."""
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", self.attrs)
        if not m or lhs_shape is None:
            return 0
        k = 1
        for d in m.group(1).split(","):
            if d != "":
                k *= lhs_shape.dims[int(d)]
        return k


@dataclass
class HLOComputation:
    name: str
    ops: List[HLOOp] = field(default_factory=list)
    params: List[HLOOp] = field(default_factory=list)

    @property
    def root(self) -> Optional[HLOOp]:
        for op in self.ops:
            if op.is_root:
                return op
        return self.ops[-1] if self.ops else None

    def op_by_name(self, name: str) -> Optional[HLOOp]:
        for op in self.ops:
            if op.name == name:
                return op
        return None


@dataclass
class HLOModule:
    name: str
    computations: Dict[str, HLOComputation]
    entry_name: str
    num_partitions: int = 1

    @property
    def entry(self) -> HLOComputation:
        return self.computations[self.entry_name]

    def collective_ops(self, computation: Optional[str] = None) -> List[HLOOp]:
        comps = (
            [self.computations[computation]] if computation
            else list(self.computations.values())
        )
        return [op for c in comps for op in c.ops if op.is_collective]


_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
# Result type matched non-greedily up to the first " opcode(" — robust to
# tuple types containing "/*index=N*/" comments.
_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _split_op_line(rest: str) -> Tuple[str, str]:
    """Split ``operands), attrs`` at the closing paren of the operand list."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_hlo(text: str) -> HLOModule:
    module_name = "module"
    num_partitions = 1
    m = re.search(r"HloModule\s+([\w.\-]+)", text)
    if m:
        module_name = m.group(1)
    m = re.search(r"num_partitions=(\d+)", text)
    if m:
        num_partitions = int(m.group(1))

    computations: Dict[str, HLOComputation] = {}
    entry_name = ""
    current: Optional[HLOComputation] = None

    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if current is None:
            hm = _COMP_HEADER_RE.match(stripped)
            if hm and "=" not in stripped.split("(")[0]:
                current = HLOComputation(name=hm.group(2))
                if hm.group(1):
                    entry_name = hm.group(2)
                continue
            continue
        if stripped == "}":
            computations[current.name] = current
            current = None
            continue
        om = _OP_RE.match(stripped)
        if not om:
            continue
        is_root = bool(om.group(1))
        name = om.group(2)
        shapes = parse_shapes(om.group(3))
        opcode = om.group(4)
        operand_str, attrs = _split_op_line(om.group(5))
        operands = tuple(_OPERAND_RE.findall(operand_str)) if opcode != "parameter" else ()
        op = HLOOp(
            name=name, opcode=opcode, shapes=shapes, operands=operands,
            attrs=attrs.strip().lstrip(","), is_root=is_root, raw=stripped,
        )
        current.ops.append(op)
        if opcode == "parameter":
            current.params.append(op)

    if current is not None:  # unterminated trailing computation
        computations[current.name] = current
    if not entry_name and computations:
        entry_name = list(computations)[-1]
    return HLOModule(
        name=module_name,
        computations=computations,
        entry_name=entry_name,
        num_partitions=num_partitions,
    )
