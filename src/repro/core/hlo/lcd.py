"""Loop-carried dependency detection for HLO ``while`` loops (paper §II-D on
TPU).

A ``while`` body maps a state tuple to a state tuple.  For each tuple element
we search the longest time-weighted path from the element's
``get-tuple-element`` reads to the value stored back at the same tuple index
in the root — a cyclic chain across iterations, exactly the paper's 2-copy
construction specialised to HLO's explicit loop-carry structure.  This is
what exposes the sequential SSM state chain in Mamba-2, the KV-cache update
chain in decode, and optimizer-state serialization in training steps.

All tuple indices of a body are searched in one batched topological sweep
(:func:`repro.core.analysis.sweep.batched_longest_paths`): one row of the
distance matrix per loop-state element, each row's allowed starts being that
element's ``get-tuple-element`` reads — the same all-sources engine the
assembly LCD uses, instead of one DP per tuple index.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.analysis.sweep import (backtrack, batched_longest_paths,
                                       is_reached, pred_csr_from_lists)
from repro.core.hlo.costs import HLOCostModel
from repro.core.hlo.machine import TPUChip, TPU_V5E
from repro.core.hlo.parser import HLOComputation, HLOModule, HLOOp, parse_hlo


@dataclass
class CarriedChain:
    while_op: str
    body: str
    tuple_index: int
    seconds: float  # one period of the carried chain
    ops: Tuple[str, ...]
    trip_count: int

    @property
    def total_seconds(self) -> float:
        return self.seconds * self.trip_count


@dataclass
class HLOLCDResult:
    chains: Tuple[CarriedChain, ...]

    @property
    def longest(self) -> Optional[CarriedChain]:
        return max(self.chains, key=lambda c: c.total_seconds, default=None)

    def render(self) -> str:
        if not self.chains:
            return "HLO LCD: no while-loop carried chains found"
        lines = ["HLO loop-carried dependency chains:"]
        for c in sorted(self.chains, key=lambda c: -c.total_seconds)[:8]:
            lines.append(
                f"  while={c.while_op} state[{c.tuple_index}] "
                f"period {c.seconds * 1e6:.2f} us x {c.trip_count} trips = "
                f"{c.total_seconds * 1e3:.3f} ms  ({len(c.ops)} ops)"
            )
        return "\n".join(lines)


_INDEX_RE = re.compile(r"index=(\d+)")


def _body_chains(
    module: HLOModule, while_op: HLOOp, body_name: str, cost: HLOCostModel,
) -> List[CarriedChain]:
    comp = module.computations.get(body_name)
    if comp is None or comp.root is None or comp.root.opcode != "tuple":
        return []
    index = {op.name: i for i, op in enumerate(comp.ops)}
    weights = [cost.op_seconds(op, comp) for op in comp.ops]

    # get-tuple-element reads of the loop state, by tuple index.
    gte_by_index: Dict[int, List[int]] = {}
    param_names = {p.name for p in comp.params}
    for i, op in enumerate(comp.ops):
        if op.opcode == "get-tuple-element" and op.operands and \
                op.operands[0] in param_names:
            m = _INDEX_RE.search(op.attrs)
            if m:
                gte_by_index.setdefault(int(m.group(1)), []).append(i)

    trips = cost.while_trip_count(while_op)
    chains: List[CarriedChain] = []
    root_operands = comp.root.operands

    # One matrix row per loop-state element; its allowed path starts are the
    # element's GTE reads.  All rows share one topological sweep.
    rows: List[Tuple[int, int, List[int]]] = []  # (tuple idx, target, starts)
    for tuple_idx, starts in gte_by_index.items():
        if tuple_idx >= len(root_operands):
            continue
        target = index.get(root_operands[tuple_idx])
        if target is None:
            continue
        rows.append((tuple_idx, target, starts))
    if not rows:
        return chains

    preds = [
        [j for operand in op.operands
         if (j := index.get(operand)) is not None and j < i]
        for i, op in enumerate(comp.ops)
    ]
    ptr, idx = pred_csr_from_lists(preds)
    D, P = batched_longest_paths(ptr, idx, np.asarray(weights, dtype=float),
                                 [starts for _, _, starts in rows])

    for row, (tuple_idx, target, _) in enumerate(rows):
        if not is_reached(D[row, target]):
            continue
        path_ids = backtrack(P[row].tolist(), target)
        if len(path_ids) <= 1:
            continue  # pass-through state (e.g. untouched weights)
        chains.append(CarriedChain(
            while_op=while_op.name, body=body_name, tuple_index=tuple_idx,
            seconds=float(D[row, target]), ops=tuple(comp.ops[v].name
                                                     for v in path_ids),
            trip_count=trips,
        ))
    return chains


def hlo_loop_carried(source, chip: TPUChip = TPU_V5E) -> HLOLCDResult:
    """``source`` is HLO text, a parsed module, or a Compiled object."""
    if hasattr(source, "as_text"):
        source = source.as_text()
    module = source if isinstance(source, HLOModule) else parse_hlo(source)
    cost = HLOCostModel(module, chip)
    chains: List[CarriedChain] = []
    for comp in module.computations.values():
        for op in comp.ops:
            if op.opcode != "while":
                continue
            body = op.body_computation
            if body is not None:
                chains.extend(_body_chains(module, op, body, cost))
    return HLOLCDResult(chains=tuple(chains))
