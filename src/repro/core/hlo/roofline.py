"""Three-term roofline from the compiled dry-run artifact (task §Roofline).

This is OSACA's throughput analysis run on the production HLO: the MXU, HBM
and ICI "ports" accumulate pressure from every op; the dominant port is the
bottleneck and its pressure the runtime lower bound.

    compute term    = HLO_FLOPs(per chip) / peak_FLOP/s
    memory term     = HLO_bytes(per chip) / HBM_bw
    collective term = collective_bytes(per chip) / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (already per-partition for
SPMD modules); collective bytes are summed over the operand sizes of every
collective op in ``compiled.as_text()``, as prescribed.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.hlo.costs import HLOCostModel
from repro.core.hlo.machine import TPUChip, TPU_V5E
from repro.core.hlo.parser import HLOModule, parse_hlo


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    total_bytes: float = 0.0
    ring_seconds: float = 0.0  # refined ring-model time (extra info)


@dataclass
class RooflineReport:
    name: str
    chip: TPUChip
    num_partitions: int
    hlo_flops: float  # per chip
    hlo_bytes: float  # per chip
    collective: CollectiveStats
    terms: Dict[str, float]  # MXU / HBM / ICI seconds
    model_flops: Optional[float] = None  # global useful FLOPs (6ND)
    memory_per_device: Optional[int] = None
    ca_raw_flops: float = 0.0  # uncorrected cost_analysis values (reference)
    ca_raw_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        return max(self.terms, key=lambda k: self.terms[k])

    @property
    def bound_seconds(self) -> float:
        return self.terms[self.dominant]

    @property
    def useful_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs (global): remat/redundancy waste catcher."""
        if self.model_flops is None or self.hlo_flops == 0:
            return None
        return self.model_flops / (self.hlo_flops * self.num_partitions)

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of compute roofline if the bound is met."""
        if self.bound_seconds == 0:
            return 0.0
        return self.terms["MXU"] / self.bound_seconds

    def recommendation(self) -> str:
        dom = self.dominant
        if dom == "MXU":
            return ("compute-bound: increase arithmetic intensity is moot - "
                    "reduce redundant FLOPs (remat policy, fused attention) "
                    f"[useful ratio {self.useful_ratio and round(self.useful_ratio, 3)}]")
        if dom == "HBM":
            return ("memory-bound: cut HBM traffic - fuse attention/softmax, "
                    "chunked loss, bf16 activations, better layouts")
        top = max(self.collective.bytes_by_op, key=lambda k: self.collective.bytes_by_op[k],
                  default="-")
        return (f"collective-bound: dominant op {top} - reshard to reduce "
                "gather volume, overlap collectives with compute, or use "
                "reduce-scatter gradient sync")

    def row(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "chips": self.num_partitions,
            "compute_s": self.terms["MXU"],
            "memory_s": self.terms["HBM"],
            "collective_s": self.terms["ICI"],
            "dominant": self.dominant,
            "bound_s": self.bound_seconds,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "collective_bytes_per_chip": self.collective.total_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_per_device": self.memory_per_device,
            "ca_raw_flops": self.ca_raw_flops,
            "ca_raw_bytes": self.ca_raw_bytes,
        }

    def render(self) -> str:
        lines = [
            f"roofline  {self.name}  ({self.chip.name} x {self.num_partitions})",
            f"  compute   (MXU): {self.terms['MXU'] * 1e3:10.3f} ms"
            f"   [{self.hlo_flops:.3e} FLOP/chip]",
            f"  memory    (HBM): {self.terms['HBM'] * 1e3:10.3f} ms"
            f"   [{self.hlo_bytes:.3e} B/chip]",
            f"  collective(ICI): {self.terms['ICI'] * 1e3:10.3f} ms"
            f"   [{self.collective.total_bytes:.3e} B/chip, "
            f"ring-model {self.collective.ring_seconds * 1e3:.3f} ms]",
            f"  dominant: {self.dominant}  -> bound {self.bound_seconds * 1e3:.3f} ms/step",
        ]
        if self.model_flops is not None:
            lines.append(
                f"  MODEL_FLOPS {self.model_flops:.3e}  useful-ratio "
                f"{self.useful_ratio:.3f}" if self.useful_ratio is not None else ""
            )
        if self.memory_per_device is not None:
            lines.append(f"  memory/device: {self.memory_per_device / 2**30:.2f} GiB")
        for op, b in sorted(self.collective.bytes_by_op.items(), key=lambda kv: -kv[1]):
            lines.append(f"    {op:<22} x{self.collective.counts[op]:<4} "
                         f"{b:.3e} B/chip")
        lines.append(f"  -> {self.recommendation()}")
        return "\n".join(l for l in lines if l)


def collective_stats(
    module: HLOModule, chip: TPUChip,
    exec_counts: Optional[Dict[str, float]] = None,
) -> CollectiveStats:
    """Sum collective operand bytes, weighting ops inside while bodies by the
    loop trip count (``exec_counts`` from the cost model)."""
    stats = CollectiveStats()
    for comp in module.computations.values():
        mult = (exec_counts or {}).get(comp.name, 1.0 if exec_counts is None else 0.0)
        if mult == 0.0:
            continue
        for op in comp.ops:
            if not op.is_collective or op.opcode.endswith("-done"):
                continue
            operand_bytes = 0.0
            for operand in op.operands:
                src = comp.op_by_name(operand)
                if src is not None:
                    operand_bytes += src.result_bytes
            base = op.opcode.replace("-start", "")
            stats.counts[base] = stats.counts.get(base, 0) + int(mult)
            stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0.0) + mult * operand_bytes
            stats.total_bytes += mult * operand_bytes
            stats.ring_seconds += mult * chip.collective_model_seconds(
                op.opcode, operand_bytes, op.replica_group_size(module.num_partitions)
            )
    return stats


def roofline_from_compiled(
    compiled,
    name: str = "step",
    chip: TPUChip = TPU_V5E,
    model_flops: Optional[float] = None,
    hlo_text: Optional[str] = None,
) -> RooflineReport:
    """Build the report from a ``jax.stages.Compiled`` artifact.

    XLA's ``cost_analysis()`` counts each ``while`` body once, so scanned-
    layer models would be undercounted by ~n_layers.  We correct by the ratio
    of the static trip-aware estimate to the trips=1 estimate (both from the
    parsed HLO itself), and scale collectives inside loop bodies by their
    execution counts.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # newer jax: one dict per program
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    ca_bytes = float(ca.get("bytes accessed", 0.0))
    module = parse_hlo(hlo_text if hlo_text is not None else compiled.as_text())

    cost_trips = HLOCostModel(module, chip, count_while_trips=True)
    cost_once = HLOCostModel(module, chip, count_while_trips=False)
    est_flops_trips = cost_trips.module_flops()
    est_flops_once = cost_once.module_flops()
    flop_corr = (est_flops_trips / est_flops_once) if est_flops_once > 0 else 1.0
    flops *= max(flop_corr, 1.0)
    # Memory term: the static trip-aware estimate.  cost_analysis counts
    # while bodies once and includes CPU-only bf16<->f32 convert buffers, so
    # neither raw nor ratio-corrected values survive loops + hoisting; the
    # static model walks scheduled computations x execution counts directly.
    byts = cost_trips.module_bytes()

    stats = collective_stats(module, chip, exec_counts=cost_trips.execution_counts())
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                  + ma.temp_size_in_bytes)
    except Exception:
        pass
    report = RooflineReport(
        name=name,
        chip=chip,
        num_partitions=module.num_partitions,
        hlo_flops=flops,
        hlo_bytes=byts,
        collective=stats,
        terms=chip.port_pressure(flops, byts, stats.total_bytes),
        model_flops=model_flops,
        memory_per_device=mem,
    )
    report.ca_raw_flops = float(ca.get("flops", 0.0))
    report.ca_raw_bytes = ca_bytes
    return report


def roofline_report(
    hlo_text: str,
    name: str = "step",
    chip: TPUChip = TPU_V5E,
    model_flops: Optional[float] = None,
    flops: Optional[float] = None,
    bytes_accessed: Optional[float] = None,
) -> RooflineReport:
    """Build the report from HLO text alone (flops/bytes estimated if absent)."""
    module = parse_hlo(hlo_text)
    stats = collective_stats(module, chip)
    cost = HLOCostModel(module, chip)
    if flops is None:
        flops = cost.computation_flops(module.entry_name)
    if bytes_accessed is None:
        bytes_accessed = sum(
            cost.op_bytes(op, module.entry) for op in module.entry.ops
        )
    return RooflineReport(
        name=name,
        chip=chip,
        num_partitions=module.num_partitions,
        hlo_flops=float(flops),
        hlo_bytes=float(bytes_accessed),
        collective=stats,
        terms=chip.port_pressure(float(flops), float(bytes_accessed), stats.total_bytes),
        model_flops=model_flops,
    )
