"""Memory hot-spot listing from compiled HLO — the dry-run "profiler".

Lists the largest tensors a module materializes (per computation, with
execution context), which is where the §Perf memory-term iterations start.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.hlo.parser import HLOModule, parse_hlo


@dataclass
class Hotspot:
    computation: str
    op_name: str
    opcode: str
    bytes: int
    shape: str

    def render(self) -> str:
        return (f"{self.bytes / 2**30:8.2f} GiB  {self.opcode:<24} "
                f"{self.computation}/{self.op_name}  {self.shape}")


def memory_hotspots(source, top_k: int = 20,
                    min_bytes: int = 64 * 1024 * 1024) -> List[Hotspot]:
    """``source``: HLO text / module / Compiled.  Largest result buffers."""
    if hasattr(source, "as_text"):
        source = source.as_text()
    module = source if isinstance(source, HLOModule) else parse_hlo(source)
    spots: List[Hotspot] = []
    for comp in module.computations.values():
        for op in comp.ops:
            if op.opcode in ("parameter", "tuple", "get-tuple-element"):
                continue
            b = op.result_bytes
            if b >= min_bytes:
                shape_str = ", ".join(
                    f"{s.dtype}{list(s.dims)}" for s in op.shapes[:3])
                spots.append(Hotspot(
                    computation=comp.name, op_name=op.name, opcode=op.opcode,
                    bytes=int(b), shape=shape_str))
    spots.sort(key=lambda h: -h.bytes)
    return spots[:top_k]


def render_hotspots(source, top_k: int = 15) -> str:
    spots = memory_hotspots(source, top_k=top_k)
    if not spots:
        return "no buffers above threshold"
    return "\n".join(h.render() for h in spots)


def cpu_bf16_artifact_bytes(source, min_bytes: int = 128 * 1024 * 1024) -> int:
    """Bytes of f32 ``convert``-of-bf16 buffers — a CPU-backend lowering
    artifact (no native bf16 dot on CPU, so XLA converts operands to f32 and
    hoists the conversions out of loops).  The TPU MXU consumes bf16
    natively, so these buffers do not exist on the target; the dry-run
    reports memory with and without them."""
    if hasattr(source, "as_text"):
        source = source.as_text()
    module = source if isinstance(source, HLOModule) else parse_hlo(source)
    total = 0
    seen = set()
    for comp in module.computations.values():
        for op in comp.ops:
            if op.opcode != "convert" or not op.shapes:
                continue
            s = op.shapes[0]
            if s.dtype != "f32" or s.bytes < min_bytes:
                continue
            src = comp.op_by_name(op.operands[0]) if op.operands else None
            src_dtype = src.shapes[0].dtype if src and src.shapes else "bf16"
            if src_dtype != "bf16":
                continue
            key = (s.dtype, s.dims)
            if key in seen:
                continue  # fusions clone converts; count unique buffers once
            seen.add(key)
            total += s.bytes
    return int(total)
