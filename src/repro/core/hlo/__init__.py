from repro.core.hlo.parser import HLOComputation, HLOModule, HLOOp, parse_hlo
from repro.core.hlo.machine import TPU_V5E, TPUChip
from repro.core.hlo.roofline import RooflineReport, roofline_from_compiled, roofline_report
from repro.core.hlo.critical_path import hlo_critical_path
from repro.core.hlo.lcd import hlo_loop_carried

__all__ = [
    "HLOComputation", "HLOModule", "HLOOp", "parse_hlo",
    "TPU_V5E", "TPUChip",
    "RooflineReport", "roofline_from_compiled", "roofline_report",
    "hlo_critical_path", "hlo_loop_carried",
]
