"""TPU chip "port model" (DESIGN.md §3).

The OSACA port-model concept carries over with the chip's concurrently
operating engines as the ports: the MXU (systolic matmul), the VPU
(vector/elementwise), the HBM interface, and the ICI links.  An HLO op's
"port pressure" is the time it occupies each engine; the roofline terms are
exactly the per-port accumulated pressures of the module.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class TPUChip:
    name: str
    peak_flops: float  # bf16 FLOP/s per chip
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link per direction
    ici_links: int  # ICI links per chip
    vmem_bytes: int
    hbm_bytes: int

    # ---- per-op port pressure (seconds) -----------------------------------

    def compute_seconds(self, flops: float) -> float:
        return flops / self.peak_flops

    def memory_seconds(self, bytes_accessed: float) -> float:
        return bytes_accessed / self.hbm_bw

    def collective_seconds(self, bytes_moved: float) -> float:
        # Task-prescribed roofline denominator: one link's bandwidth.
        return bytes_moved / self.ici_bw

    def collective_model_seconds(self, opcode: str, operand_bytes: float,
                                 group_size: int) -> float:
        """Ring-model refinement: bytes each chip moves over ICI.

        all-reduce     : 2 (n-1)/n x B       (reduce-scatter + all-gather)
        all-gather     : (n-1) x B           (operand B is the local shard)
        reduce-scatter : (n-1)/n x B
        all-to-all     : (n-1)/n x B
        collective-permute : B
        """
        n = max(group_size, 1)
        base = opcode.replace("-start", "").replace("-done", "")
        if n == 1:
            return 0.0
        mult = {
            "all-reduce": 2.0 * (n - 1) / n,
            "all-gather": float(n - 1),
            "reduce-scatter": (n - 1) / n,
            "all-to-all": (n - 1) / n,
            "collective-permute": 1.0,
            "collective-broadcast": 1.0,
        }.get(base, 1.0)
        return mult * operand_bytes / self.ici_bw

    def port_pressure(self, flops: float, bytes_accessed: float,
                      collective_bytes: float) -> Dict[str, float]:
        """The module-level three-term pressure (seconds per port)."""
        return {
            "MXU": self.compute_seconds(flops),
            "HBM": self.memory_seconds(bytes_accessed),
            "ICI": self.collective_seconds(collective_bytes),
        }


# TPU v5e per task spec: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E = TPUChip(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    ici_links=4,
    vmem_bytes=128 * 1024 * 1024,
    hbm_bytes=16 * 1024**3,
)
