"""Per-op FLOP/byte/time estimation over parsed HLO.

This is the "instruction database" role for the TPU port model: where the
x86/ARM DBs store measured latencies, HLO op costs are derived from shapes
(the op's semantics fix its arithmetic and data volume).  ``cost_analysis()``
from the compiled executable remains the authoritative module-level number;
these per-op estimates weight the critical-path / LCD graphs.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.hlo.machine import TPUChip
from repro.core.hlo.parser import HLOComputation, HLOModule, HLOOp

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "compare", "select", "and", "or", "xor", "not",
    "clamp", "floor", "ceil", "round-nearest-afz", "power", "remainder",
}
_TRANSCENDENTAL = {"exp", "expm1", "log", "log1p", "tanh", "rsqrt", "sqrt",
                   "logistic", "sin", "cos", "atan2", "erf", "cbrt"}
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "bitcast-convert", "reshape", "after-all", "partition-id", "replica-id",
         "opt-barrier", "custom-call", "rng-bit-generator", "iota"}


@dataclass
class OpCost:
    flops: float
    bytes: float
    seconds: float


class HLOCostModel:
    def __init__(self, module: HLOModule, chip: TPUChip,
                 default_while_trips: int = 1,
                 count_while_trips: bool = True):
        self.module = module
        self.chip = chip
        self.default_while_trips = default_while_trips
        self.count_while_trips = count_while_trips
        self._comp_flops: Dict[str, float] = {}
        self._comp_bytes: Dict[str, float] = {}
        self._const_ints: Dict[str, int] = {}
        self._index_constants()

    # -- helpers -------------------------------------------------------------

    def _index_constants(self) -> None:
        pat = re.compile(r"constant\((\d+)\)")
        for comp in self.module.computations.values():
            for op in comp.ops:
                if op.opcode == "constant":
                    m = pat.search(op.raw)
                    if m:
                        self._const_ints[f"{comp.name}/{op.name}"] = int(m.group(1))

    def while_trip_count(self, op: HLOOp) -> int:
        """Trip count: XLA's backend_config when present, else inferred from
        ``compare(induction, constant)`` in the cond."""
        known = op.known_trip_count
        if known is not None:
            return max(known, 1)
        cname = op.condition_computation
        comp = self.module.computations.get(cname) if cname else None
        if comp is not None and comp.root is not None:
            root = comp.root
            compare = root
            if root.opcode != "compare":
                # Root may be a fusion over the compare; look for any compare.
                compare = next((o for o in comp.ops if o.opcode == "compare"), root)
            for operand in compare.operands:
                val = self._const_ints.get(f"{comp.name}/{operand}")
                if val is not None:
                    return max(val, 1)
        return self.default_while_trips

    # -- FLOPs ---------------------------------------------------------------

    def op_flops(self, op: HLOOp, comp: HLOComputation) -> float:
        opc = op.opcode
        if opc in _FREE or opc == "parameter":
            return 0.0
        if opc == "dot":
            lhs = comp.op_by_name(op.operands[0]) if op.operands else None
            lhs_shape = lhs.shapes[0] if lhs and lhs.shapes else None
            k = op.dot_contracting(lhs_shape)
            out = sum(s.elements for s in op.shapes)
            return 2.0 * out * max(k, 1)
        if opc == "convolution":
            out = sum(s.elements for s in op.shapes)
            m = re.search(r"window=\{size=([\dx]+)", op.attrs)
            k = 1
            if m:
                for d in m.group(1).split("x"):
                    k *= int(d)
            return 2.0 * out * k
        if opc in ("fusion", "call"):
            total = 0.0
            for cname in op.called_computations:
                total += self.computation_flops(cname)
            return total
        if opc == "while":
            trips = self.while_trip_count(op) if self.count_while_trips else 1
            body = op.body_computation
            return trips * (self.computation_flops(body) if body else 0.0)
        if opc == "conditional":
            return max((self.computation_flops(c) for c in op.called_computations),
                       default=0.0)
        if opc in ("reduce", "reduce-window"):
            operand = comp.op_by_name(op.operands[0]) if op.operands else None
            return float(operand.shapes[0].elements) if operand and operand.shapes else 0.0
        out = sum(s.elements for s in op.shapes)
        if opc in _TRANSCENDENTAL:
            return 4.0 * out
        if opc in _ELEMENTWISE:
            return float(out)
        if opc in ("scatter", "gather", "dynamic-slice", "dynamic-update-slice",
                   "sort", "map", "select-and-scatter"):
            return float(out)
        return 0.0

    def computation_flops(self, name: Optional[str]) -> float:
        if name is None or name not in self.module.computations:
            return 0.0
        if name in self._comp_flops:
            return self._comp_flops[name]
        self._comp_flops[name] = 0.0  # cycle guard
        comp = self.module.computations[name]
        total = sum(self.op_flops(op, comp) for op in comp.ops)
        self._comp_flops[name] = total
        return total

    # -- execution counts ------------------------------------------------------

    def execution_counts(self, scheduled_only: bool = False) -> Dict[str, float]:
        """How many times each computation executes per entry invocation.

        Needed because post-optimization HLO text contains while bodies once:
        collectives (and flops/bytes) inside them run trip-count times.
        ``scheduled_only`` restricts the walk to computations whose ops are
        actually scheduled against HBM (entry, while bodies/conds,
        conditional branches, calls) — fusion/reducer bodies execute in
        registers/VMEM and must not contribute HBM-byte estimates.
        """
        counts: Dict[str, float] = {}

        def visit(name: str, mult: float, depth: int = 0) -> None:
            if depth > 32 or name not in self.module.computations:
                return
            counts[name] = counts.get(name, 0.0) + mult
            comp = self.module.computations[name]
            for op in comp.ops:
                if op.opcode == "while":
                    trips = self.while_trip_count(op) if self.count_while_trips else 1
                    if op.body_computation:
                        visit(op.body_computation, mult * trips, depth + 1)
                    if op.condition_computation:
                        visit(op.condition_computation, mult * (trips + 1), depth + 1)
                elif op.opcode in ("call", "conditional"):
                    for cname in op.called_computations:
                        visit(cname, mult, depth + 1)
                elif not scheduled_only and op.opcode in (
                        "fusion", "reduce", "reduce-window", "scatter",
                        "sort", "map"):
                    for cname in op.called_computations:
                        visit(cname, mult, depth + 1)

        visit(self.module.entry_name, 1.0)
        return counts

    def module_bytes(self) -> float:
        """Trip-aware HBM-traffic estimate: scheduled computations only, with
        fusion ops contributing their operand+result bytes (their bodies run
        out of VMEM).  ``convert``/``copy``-only dtype plumbing is excluded:
        bf16<->f32 converts are CPU-lowering artifacts absent on the TPU
        target (the MXU consumes bf16 natively)."""
        counts = self.execution_counts(scheduled_only=True)
        total = 0.0
        for name, mult in counts.items():
            comp = self.module.computations[name]
            for op in comp.ops:
                if op.opcode in ("while", "conditional", "call", "convert",
                                 "bitcast", "copy"):
                    continue  # callees via their own computations; converts
                              # and copies are dtype/layout plumbing
                if op.opcode == "fusion" and self._is_dtype_plumbing(op):
                    continue
                total += mult * self.op_bytes(op, comp)
        return total

    def _fusion_bytes(self, op: HLOOp) -> Optional[float]:
        """Body-aware HBM traffic of a fusion.

        Reads: per fused parameter, bytes actually touched — a parameter
        consumed only through dynamic-slice (possibly via transparent
        convert/bitcast) is read slice-sized; a dynamic-update-slice target
        is aliased (no read).  Write: the DUS update size when the root is a
        DUS (in-place), else the result.  This models TPU buffer aliasing
        where the CPU text shows hoisted f32 copies.
        """
        called = None
        for cname in op.called_computations:
            called = self.module.computations.get(cname)
            if called is not None:
                break
        if called is None or called.root is None:
            return None

        index = {o.name: o for o in called.ops}
        consumers: Dict[str, list] = {}
        for o in called.ops:
            for operand in o.operands:
                consumers.setdefault(operand, []).append(o)
        transparent = {"convert", "bitcast"}

        def touched(param: HLOOp) -> float:
            size = float(param.result_bytes)
            total_t = 0.0
            frontier = [param.name]
            seen = set()
            while frontier:
                nm = frontier.pop()
                if nm in seen:
                    continue
                seen.add(nm)
                for c in consumers.get(nm, []):
                    if c.opcode in transparent:
                        frontier.append(c.name)
                    elif c.opcode == "dynamic-slice":
                        total_t += float(c.result_bytes)
                    elif c.opcode == "dynamic-update-slice" and \
                            c.operands and c.operands[0] == nm:
                        continue  # aliased in-place target: no read
                    else:
                        return size  # fully consumed
            return min(total_t, size)

        reads = sum(touched(p) for p in called.params)
        root = called.root
        if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
            upd = index.get(root.operands[1])
            write = float(upd.result_bytes) if upd and upd.shapes else \
                float(root.result_bytes)
        else:
            write = float(op.result_bytes)
        return reads + write

    def _is_dtype_plumbing(self, op: HLOOp) -> bool:
        """Fusion whose body only converts/copies (wrapped_convert etc.)."""
        plumbing = {"parameter", "convert", "bitcast", "copy", "tuple",
                    "get-tuple-element", "reshape", "transpose"}
        for cname in op.called_computations:
            comp = self.module.computations.get(cname)
            if comp is None:
                return False
            if any(o.opcode not in plumbing for o in comp.ops):
                return False
        return bool(op.called_computations)

    def module_flops(self) -> float:
        """Trip-aware FLOP estimate (callee flops via call sites, once)."""
        return self.computation_flops(self.module.entry_name)

    # -- bytes & time ---------------------------------------------------------

    def op_bytes(self, op: HLOOp, comp: HLOComputation) -> float:
        """HBM traffic estimate: operand reads + result write."""
        if op.opcode in _FREE:
            return 0.0
        if op.opcode == "dynamic-update-slice":
            # In-place update (XLA aliases the buffer): traffic = update
            # read + write, not the whole operand.
            upd = comp.op_by_name(op.operands[1]) if len(op.operands) > 1 else None
            return 2.0 * (upd.result_bytes if upd and upd.shapes else 0.0)
        if op.opcode == "dynamic-slice":
            return 2.0 * float(op.result_bytes)
        if op.opcode == "fusion":
            fused = self._fusion_bytes(op)
            if fused is not None:
                return fused
        total = float(op.result_bytes)
        for operand in op.operands:
            src = comp.op_by_name(operand)
            if src is not None:
                total += src.result_bytes
        return total

    def op_seconds(self, op: HLOOp, comp: HLOComputation) -> float:
        """Node weight: time on the op's bottleneck engine."""
        if op.is_collective:
            operand_bytes = 0.0
            for operand in op.operands:
                src = comp.op_by_name(operand)
                if src is not None:
                    operand_bytes += src.result_bytes
            group = op.replica_group_size(self.module.num_partitions)
            return self.chip.collective_model_seconds(op.opcode, operand_bytes, group)
        flops = self.op_flops(op, comp)
        mem = self.op_bytes(op, comp)
        return max(self.chip.compute_seconds(flops), self.chip.memory_seconds(mem))
