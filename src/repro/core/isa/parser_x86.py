"""x86-64 AT&T-syntax assembly parser (icc/ifort/gcc ``-S`` output style).

AT&T operand order: sources first, destination last.  SSE/ALU two-operand
forms read-modify-write the destination; AVX three-operand forms do not.
"""

from __future__ import annotations

import re
from typing import List, Optional

from repro.core.isa.instruction import (
    Immediate,
    InstructionForm,
    Kernel,
    Label,
    MemoryRef,
    Register,
    extract_marked_region,
)

_GPR64 = {f"r{n}" for n in ("ax", "bx", "cx", "dx", "si", "di", "bp", "sp")} | {
    f"r{i}" for i in range(8, 16)
}
# alias -> (canonical 64-bit name, access width in bits).  Every legacy
# sub-register names the same architectural register for dependency tracking.
_GPR_ALIAS = {}
for _base in ("ax", "bx", "cx", "dx", "si", "di", "bp", "sp"):
    _GPR_ALIAS[f"e{_base}"] = (f"r{_base}", 32)
    _GPR_ALIAS[_base] = (f"r{_base}", 16)
for _low, _full in (("al", "rax"), ("bl", "rbx"), ("cl", "rcx"),
                    ("dl", "rdx"), ("ah", "rax"), ("bh", "rbx"),
                    ("ch", "rcx"), ("dh", "rdx"), ("sil", "rsi"),
                    ("dil", "rdi"), ("bpl", "rbp"), ("spl", "rsp")):
    _GPR_ALIAS[_low] = (_full, 8)
for _i in range(8, 16):
    _GPR_ALIAS[f"r{_i}d"] = (f"r{_i}", 32)
    _GPR_ALIAS[f"r{_i}w"] = (f"r{_i}", 16)
    _GPR_ALIAS[f"r{_i}b"] = (f"r{_i}", 8)

_VEC_RE = re.compile(r"^(x|y|z)mm(\d+)$")

_BRANCH_RE = re.compile(r"^(jmp|ja|jae|jb|jbe|jc|je|jg|jge|jl|jle|jna|jne|jno|jnp|jns|jnz|jo|jp|js|jz|call|ret|loop)")
_NO_DEST = {"cmp", "cmpq", "cmpl", "cmpb", "cmpw", "test", "testq", "testl", "nop",
            "ucomisd", "ucomiss", "comisd", "comiss", "prefetcht0", "prefetcht1", "prefetchnta"}
# Pure-move mnemonics: destination is written, not read.
_MOVES = re.compile(r"^v?(mov|lea|broadcast|cvt|pmov)")
_RMW_SUFFIXES = ("q", "l", "w", "b", "")


def _parse_register(tok: str) -> Optional[Register]:
    tok = tok.strip().lstrip("%")
    if not tok:
        return None
    m = _VEC_RE.match(tok)
    if m:
        # xmm/ymm/zmm alias the same architectural register.
        return Register(name=f"xmm{m.group(2)}", cls="fpr",
                        width={"x": 128, "y": 256, "z": 512}[m.group(1)])
    if tok in _GPR64:
        return Register(name=tok, cls="gpr", width=64)
    if tok in _GPR_ALIAS:
        name, width = _GPR_ALIAS[tok]
        return Register(name=name, cls="gpr", width=width)
    if tok == "rip":
        return Register(name="rip", cls="gpr", width=64)
    return None


_MEM_RE = re.compile(r"^(-?(?:0x[0-9a-fA-F]+|\d+))?\(([^)]*)\)$")


def _parse_memory(tok: str) -> Optional[MemoryRef]:
    m = _MEM_RE.match(tok.strip())
    if not m:
        return None
    offset = int(m.group(1), 0) if m.group(1) else 0
    inner = [p.strip() for p in m.group(2).split(",")]
    base = _parse_register(inner[0]) if inner and inner[0] else None
    index = _parse_register(inner[1]) if len(inner) > 1 and inner[1] else None
    scale = int(inner[2]) if len(inner) > 2 and inner[2] else 1
    return MemoryRef(base=base, index=index, scale=scale, offset=offset)


def _split_operands(body: str) -> List[str]:
    parts, depth, cur = [], 0, []
    for ch in body:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return [p for p in parts if p]


_ZERO_IDIOM_RE = re.compile(
    r"^v?(xor|pxor|xorps|xorpd|pxord)\w*\s+(\S+),\s*(\S+)(,\s*(\S+))?\s*$"
)


def _is_zero_idiom(code: str) -> bool:
    m = _ZERO_IDIOM_RE.match(code)
    if not m:
        return False
    ops = [m.group(2).rstrip(","), m.group(3).rstrip(",")]
    if m.group(5):
        ops.append(m.group(5))
    return len(set(ops)) == 1


def parse_line_x86(line: str, line_number: int = 0) -> Optional[InstructionForm]:
    raw = line
    code = line.split("#")[0].strip()
    if not code or code.startswith((".", "/")) or code.endswith(":"):
        return None
    m = re.match(r"^(\S+)\s*(.*)$", code)
    mnemonic = m.group(1).lower()
    body = m.group(2).strip()
    toks = _split_operands(body)

    operands: List[object] = []
    for tok in toks:
        if tok.startswith("$"):
            try:
                operands.append(Immediate(int(tok[1:], 0)))
            except ValueError:
                operands.append(Immediate(0))
            continue
        reg = _parse_register(tok)
        if reg is not None:
            operands.append(reg)
            continue
        mem = _parse_memory(tok)
        if mem is not None:
            operands.append(mem)
            continue
        operands.append(Label(tok))

    is_branch = bool(_BRANCH_RE.match(mnemonic))
    loads: List[MemoryRef] = []
    stores: List[MemoryRef] = []
    sources: List[str] = []
    dests: List[str] = []

    if is_branch or mnemonic in _NO_DEST:
        for op in operands:
            if isinstance(op, Register):
                sources.append(op.name)
            elif isinstance(op, MemoryRef):
                loads.append(op)
                sources.extend(r.name for r in op.address_registers)
    elif operands:
        *srcs, dst = operands
        if isinstance(dst, MemoryRef):
            stores.append(dst)
            sources.extend(r.name for r in dst.address_registers)
        elif isinstance(dst, Register):
            dests.append(dst.name)
            # Two-operand RMW forms read the destination too (not moves).
            if len(operands) == 2 and not _MOVES.match(mnemonic):
                sources.append(dst.name)
        for op in srcs:
            if isinstance(op, Register):
                sources.append(op.name)
            elif isinstance(op, MemoryRef):
                # lea computes the effective address without touching memory:
                # pure address arithmetic, no load µ-op, no load-latency
                # vertex — its address registers are plain sources.
                if not mnemonic.startswith("lea"):
                    loads.append(op)
                sources.extend(r.name for r in op.address_registers)

    is_dep_breaking = _is_zero_idiom(code)
    if is_dep_breaking:
        sources = [s for s in sources if s not in dests]

    return InstructionForm(
        mnemonic=mnemonic,
        operands=tuple(operands),
        source_registers=tuple(sources),
        dest_registers=tuple(dests),
        loads=tuple(loads),
        stores=tuple(stores),
        is_branch=is_branch,
        is_dep_breaking=is_dep_breaking,
        line_number=line_number,
        raw=raw,
    )


def parse_x86(asm: str, name: str = "kernel") -> Kernel:
    """Parse marked x86-64 AT&T assembly into a :class:`Kernel`."""
    lines = asm.splitlines()
    start, end = extract_marked_region(lines)
    instrs: List[InstructionForm] = []
    for idx in range(start, end):
        form = parse_line_x86(lines[idx], line_number=idx + 1)
        if form is not None:
            instrs.append(form)
    return Kernel(instructions=tuple(instrs), isa="x86", name=name,
                  source_lines=(start + 1, end))
