"""AArch64 (A64) assembly parser producing :class:`InstructionForm` streams.

Coverage targets GCC/armclang output for HPC loop kernels: data processing,
scalar/vector FP, loads/stores with immediate / register(+shift) offsets and
pre-/post-index writeback, compare and branch.  Unknown mnemonics still parse
(operands are classified structurally), so the instruction database remains
the single source of truth for costs.
"""

from __future__ import annotations

import re
from typing import List, Optional, Sequence, Tuple

from repro.core.isa.instruction import (
    Immediate,
    InstructionForm,
    Kernel,
    Label,
    MemoryRef,
    Register,
    extract_marked_region,
)

_GPR_RE = re.compile(r"^(x|w)(\d+|zr)$")
_FPR_RE = re.compile(r"^(b|h|s|d|q)(\d+)$")
_VEC_RE = re.compile(r"^v(\d+)(\.\w+)?$")
_WIDTH = {"b": 8, "h": 16, "s": 32, "d": 64, "q": 128}

_STORE_MNEMONICS = {"str", "strb", "strh", "stur", "stp", "st1", "st2"}
_LOAD_MNEMONICS = {"ldr", "ldrb", "ldrh", "ldur", "ldp", "ld1", "ld2", "ldrsw"}
# Loads writing *all* their register operands (pair / structure forms):
# ``ldp x0, x1, [sp]`` defines both x0 and x1.
_MULTI_DEST_LOADS = {"ldp", "ldnp", "ldxp", "ldaxp", "ld1", "ld2", "ld3", "ld4"}
_BRANCH_RE = re.compile(r"^(b|br|bl|blr|cbz|cbnz|tbz|tbnz|b\.\w+|bne|beq|bgt|blt|bge|ble|bhi|bls)$")
# Mnemonics whose first operand is *not* a destination.
_NO_DEST = {"cmp", "cmn", "tst", "prfm", "nop"} | _STORE_MNEMONICS


def _parse_register(tok: str) -> Optional[Register]:
    tok = tok.strip()
    m = _GPR_RE.match(tok)
    if m:
        if m.group(2) == "zr":
            # xzr/wzr: reads-as-zero, writes discarded.  Parsed as a register
            # (operand signatures stay stable) but excluded from dependency
            # extraction below — the zero register never carries a value.
            return Register(name="xzr", cls="gpr",
                            width=64 if m.group(1) == "x" else 32)
        return Register(name=f"x{m.group(2)}", cls="gpr", width=64 if m.group(1) == "x" else 32)
    if tok == "sp":
        return Register(name="sp", cls="gpr", width=64)
    m = _FPR_RE.match(tok)
    if m:
        return Register(name=f"v{m.group(2)}", cls="fpr", width=_WIDTH[m.group(1)])
    m = _VEC_RE.match(tok)
    if m:
        return Register(name=f"v{m.group(1)}", cls="vec", width=128)
    return None


def _parse_immediate(tok: str) -> Optional[Immediate]:
    tok = tok.strip().lstrip("#")
    try:
        return Immediate(int(tok, 0))
    except ValueError:
        return None


def _split_operands(body: str) -> List[str]:
    """Split an operand string on commas not inside brackets or braces
    (``{v0.2d, v1.2d}`` structure register lists stay one token)."""
    parts, depth, cur = [], 0, []
    for ch in body:
        if ch in "[{":
            depth += 1
        elif ch in "]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return [p for p in parts if p]


_SHIFT_RE = re.compile(r"(lsl|lsr|asr|sxtw|uxtw|sxtx)\s*#?(\d+)?", re.IGNORECASE)


def _parse_memory(tok: str, post_imm: Optional[str]) -> Optional[MemoryRef]:
    tok = tok.strip()
    if not tok.startswith("["):
        return None
    pre_index = tok.endswith("!")
    inner = tok.strip("!").strip()[1:-1]
    parts = [p.strip() for p in inner.split(",")]
    base = _parse_register(parts[0]) if parts else None
    index = None
    scale = 1
    offset = 0
    for part in parts[1:]:
        reg = _parse_register(part)
        if reg is not None:
            index = reg
            continue
        m = _SHIFT_RE.match(part)
        if m:
            amount = int(m.group(2) or 0)
            scale = 1 << amount if m.group(1).lower() == "lsl" else 1
            continue
        imm = _parse_immediate(part)
        if imm is not None:
            offset = imm.value
    post_index = post_imm is not None
    if post_imm is not None:
        imm = _parse_immediate(post_imm)
        offset = imm.value if imm else 0
    return MemoryRef(
        base=base, index=index, scale=scale, offset=offset,
        post_index=post_index, pre_index=pre_index,
    )


_ZERO_IDIOMS = (
    re.compile(r"^eor\s+(\S+),\s*(\S+),\s*\2", re.IGNORECASE),
    re.compile(r"^movi?\s+\S+,\s*#?0(?!\d)", re.IGNORECASE),
)


def parse_line_aarch64(line: str, line_number: int = 0) -> Optional[InstructionForm]:
    raw = line
    code = line.split("//")[0]
    comment_idx = code.find("#")
    comment = ""
    # ``#`` introduces immediates too; only treat as comment when preceded by
    # whitespace and followed by a non-digit.
    if comment_idx > 0 and code[comment_idx - 1].isspace():
        tail = code[comment_idx + 1:].lstrip()
        if tail and not tail[0].isdigit() and not tail[0] == "-":
            comment = tail.strip()
            code = code[:comment_idx]
    code = code.strip()
    if not code or code.startswith((".", "//", ";")) or code.endswith(":"):
        return None

    m = re.match(r"^(\S+)\s*(.*)$", code)
    mnemonic = m.group(1).lower()
    body = m.group(2).strip()

    toks = _split_operands(body)
    operands: List[object] = []
    loads: List[MemoryRef] = []
    stores: List[MemoryRef] = []
    is_store = mnemonic in _STORE_MNEMONICS
    is_load = mnemonic in _LOAD_MNEMONICS
    is_branch = bool(_BRANCH_RE.match(mnemonic))

    i = 0
    while i < len(toks):
        tok = toks[i]
        if tok.startswith("["):
            post_imm = None
            if i + 1 < len(toks) and _parse_immediate(toks[i + 1]) is not None and tok.endswith("]"):
                post_imm = toks[i + 1]
                i += 1
            mem = _parse_memory(tok, post_imm)
            if mem is not None:
                operands.append(mem)
                (stores if is_store else loads).append(mem)
            i += 1
            continue
        if tok.startswith("{"):
            # Structure register list: ``{v0.2d, v1.2d}`` — one register
            # operand per listed element.
            for sub in tok.strip("{}").split(","):
                reg = _parse_register(sub)
                if reg is not None:
                    operands.append(reg)
            i += 1
            continue
        reg = _parse_register(tok)
        if reg is not None:
            operands.append(reg)
            i += 1
            continue
        imm = _parse_immediate(tok)
        if imm is not None:
            operands.append(imm)
            i += 1
            continue
        if _SHIFT_RE.match(tok):
            i += 1
            continue
        operands.append(Label(tok))
        i += 1

    # Dependency extraction ------------------------------------------------
    sources: List[str] = []
    dests: List[str] = []
    regs = [op for op in operands if isinstance(op, Register)]
    if is_branch or mnemonic in _NO_DEST:
        sources.extend(r.name for r in regs)
    elif mnemonic in _MULTI_DEST_LOADS:
        # Pair/structure loads write every register operand, not just the
        # first: ``ldp x0, x1, [sp]`` defines both x0 and x1.
        dests.extend(r.name for r in regs)
    elif regs:
        dests.append(regs[0].name)
        sources.extend(r.name for r in regs[1:])
    for memref in loads + stores:
        sources.extend(r.name for r in memref.address_registers)
        if memref.post_index or memref.pre_index:
            if memref.base is not None:
                dests.append(memref.base.name)

    # The zero register carries no value: writes are discarded (no def, so
    # no dependency edges hang off it) and reads are constant-zero.
    sources = [s for s in sources if s != "xzr"]
    dests = [d for d in dests if d != "xzr"]

    is_dep_breaking = any(p.match(code) for p in _ZERO_IDIOMS)
    if is_dep_breaking:
        sources = [s for s in sources if s not in dests]

    return InstructionForm(
        mnemonic=mnemonic,
        operands=tuple(operands),
        source_registers=tuple(sources),
        dest_registers=tuple(dests),
        loads=tuple(loads),
        stores=tuple(stores),
        is_branch=is_branch,
        is_dep_breaking=is_dep_breaking,
        line_number=line_number,
        raw=raw,
        comment=comment,
    )


def parse_aarch64(asm: str, name: str = "kernel") -> Kernel:
    """Parse marked AArch64 assembly into a :class:`Kernel`."""
    lines = asm.splitlines()
    start, end = extract_marked_region(lines)
    instrs: List[InstructionForm] = []
    for idx in range(start, end):
        form = parse_line_aarch64(lines[idx], line_number=idx + 1)
        if form is not None:
            instrs.append(form)
    return Kernel(instructions=tuple(instrs), isa="aarch64", name=name,
                  source_lines=(start + 1, end))
