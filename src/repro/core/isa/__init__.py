from repro.core.isa.instruction import (
    Immediate,
    InstructionForm,
    Kernel,
    Label,
    MemoryRef,
    Register,
)
from repro.core.isa.parser_aarch64 import parse_aarch64
from repro.core.isa.parser_x86 import parse_x86

__all__ = [
    "Immediate",
    "InstructionForm",
    "Kernel",
    "Label",
    "MemoryRef",
    "Register",
    "parse_aarch64",
    "parse_x86",
]
