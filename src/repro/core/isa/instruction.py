"""Instruction-form model shared by the x86 and AArch64 front-ends.

This follows OSACA's notion of an *instruction form*: a mnemonic plus the
shapes of its operands (register class / immediate / memory reference).  The
analyses (throughput, critical path, loop-carried dependencies) only ever see
these normalized objects, never raw assembly text.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Operands
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Register:
    """An architectural register, normalized to its widest aliasing name.

    ``name``  -- canonical name used for dependency tracking (e.g. ``rax`` for
                 ``eax``/``ax``/``al``; ``v0`` for ``d0``/``s0``/``q0``).
    ``cls``   -- coarse register class: ``gpr`` | ``fpr`` | ``vec`` | ``flag``.
    ``width`` -- access width in bits as written in the assembly (64 for
                 ``d0``, 128 for ``q0``, ...). Only informational.
    """

    name: str
    cls: str = "gpr"
    width: int = 64

    def __str__(self) -> str:  # pragma: no cover - debugging nicety
        return self.name


@dataclass(frozen=True)
class Immediate:
    value: int

    def __str__(self) -> str:  # pragma: no cover
        return f"#{self.value}"


@dataclass(frozen=True)
class Label:
    name: str

    def __str__(self) -> str:  # pragma: no cover
        return self.name


@dataclass(frozen=True)
class MemoryRef:
    """``offset(base, index, scale)`` (x86) / ``[base, index|imm]`` (AArch64).

    ``post_index``/``pre_index`` mark AArch64 writeback forms, which update the
    base register and therefore make it a *destination* of the instruction.
    """

    base: Optional[Register] = None
    index: Optional[Register] = None
    scale: int = 1
    offset: int = 0
    post_index: bool = False
    pre_index: bool = False

    @property
    def address_registers(self) -> Tuple[Register, ...]:
        regs = []
        if self.base is not None:
            regs.append(self.base)
        if self.index is not None:
            regs.append(self.index)
        return tuple(regs)

    def __str__(self) -> str:  # pragma: no cover
        parts = [r.name for r in self.address_registers]
        return f"mem[{'+'.join(parts)}{'+' if parts else ''}{self.offset}]"


# ---------------------------------------------------------------------------
# Instruction form
# ---------------------------------------------------------------------------


@dataclass
class InstructionForm:
    mnemonic: str
    operands: Tuple[object, ...] = ()
    # Dependency sets (canonical register names).
    source_registers: Tuple[str, ...] = ()
    dest_registers: Tuple[str, ...] = ()
    # Memory behaviour: at most one load and one store per instruction form in
    # the kernels we model (true for both ISAs' loop code).
    loads: Tuple[MemoryRef, ...] = ()
    stores: Tuple[MemoryRef, ...] = ()
    is_branch: bool = False
    is_dep_breaking: bool = False  # zero idioms: xorps %x,%x / movi v0, #0
    line_number: int = 0
    raw: str = ""
    comment: str = ""

    # Filled by the machine model during analysis.
    def operand_signature(self) -> str:
        """A short signature used for instruction-database lookup.

        ``r`` = gpr, ``f`` = scalar FP reg, ``v`` = vector reg, ``i`` =
        immediate, ``m`` = memory, ``l`` = label.
        """
        sig = []
        for op in self.operands:
            if isinstance(op, Register):
                sig.append({"gpr": "r", "fpr": "f", "vec": "v", "flag": "c"}[op.cls])
            elif isinstance(op, Immediate):
                sig.append("i")
            elif isinstance(op, MemoryRef):
                sig.append("m")
            elif isinstance(op, Label):
                sig.append("l")
            else:  # pragma: no cover - defensive
                sig.append("?")
        return "".join(sig)

    @property
    def key(self) -> str:
        return f"{self.mnemonic}:{self.operand_signature()}"

    def __str__(self) -> str:  # pragma: no cover
        return self.raw.strip() or self.mnemonic


@dataclass
class Kernel:
    """A marked loop body: the unit of analysis."""

    instructions: Tuple[InstructionForm, ...]
    isa: str  # "x86" | "aarch64"
    name: str = "kernel"
    source_lines: Tuple[int, int] = (0, 0)

    def __len__(self) -> int:
        return len(self.instructions)

    def __iter__(self):
        return iter(self.instructions)

    def without_branches(self) -> "Kernel":
        return Kernel(
            instructions=tuple(i for i in self.instructions if not i.is_branch),
            isa=self.isa,
            name=self.name,
            source_lines=self.source_lines,
        )


# ---------------------------------------------------------------------------
# Marker extraction (shared helper)
# ---------------------------------------------------------------------------

OSACA_START = "OSACA-BEGIN"
OSACA_END = "OSACA-END"

# IACA byte markers.  ``movl $111, %ebx`` + ``.byte 100,103,144`` marks the
# start, ``movl $222, %ebx`` + the same byte triplet marks the end.  For ARM
# OSACA uses the analogous ``mov x1, #111`` pattern.
_IACA_START_HINTS = ("$111", "#111")
_IACA_END_HINTS = ("$222", "#222")


def extract_marked_region(lines: Sequence[str]) -> Tuple[int, int]:
    """Return (start, end) line indices of the marked kernel body.

    Supports OSACA comment markers (``# OSACA-BEGIN`` / ``# OSACA-END``), IACA
    byte markers on both ISAs, and falls back to innermost-loop detection
    (label ... conditional branch back to the same label).
    """
    start = end = None
    for i, line in enumerate(lines):
        if OSACA_START in line:
            start = i + 1
        elif OSACA_END in line:
            end = i
    if start is not None and end is not None and start < end:
        return start, end

    # IACA byte markers: marker mov, then .byte line; kernel starts after.
    pending = None
    for i, line in enumerate(lines):
        stripped = line.strip()
        if any(h in stripped for h in _IACA_START_HINTS) and stripped.startswith(("mov", "movl")):
            pending = "start"
        elif any(h in stripped for h in _IACA_END_HINTS) and stripped.startswith(("mov", "movl")):
            if start is not None:
                end = i
            pending = None
        elif stripped.startswith(".byte") and pending == "start":
            start = i + 1
            pending = None
    if start is not None and end is not None and start < end:
        return start, end

    # Fallback: innermost loop = last label that a later branch jumps back to.
    label_pos = {}
    best = None
    for i, line in enumerate(lines):
        stripped = line.strip()
        if stripped.endswith(":") and not stripped.startswith("."):
            label_pos[stripped[:-1]] = i
        elif stripped.endswith(":"):
            label_pos[stripped[:-1]] = i
        tokens = stripped.replace(",", " ").split()
        if tokens and tokens[0].startswith(("b", "j")) and len(tokens) >= 2:
            target = tokens[-1]
            if target in label_pos and label_pos[target] < i:
                span = (label_pos[target] + 1, i + 1)
                if best is None or (span[1] - span[0]) < (best[1] - best[0]):
                    best = span
    if best is not None:
        return best
    return 0, len(lines)
