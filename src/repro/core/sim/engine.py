"""Window-limited out-of-order simulator (ROADMAP open item 1).

The paper's analyses bracket a kernel's steady-state cost: the port-pressure
throughput bound assumes an *infinite* scheduling window, the critical path
assumes *no* resource limits.  Real cores sit between the two because the
out-of-order window is finite — uiCA (arXiv:2107.14210) demonstrates that
modeling the frontend width, ROB/scheduler/LSQ capacities, and in-order
retirement is what turns the bracket into a point prediction.  This module
is that model at the resolution of our machine DBs.

Mechanics
---------
The simulator replays the kernel's dependency DAG over ``K`` back-to-back
body copies.  Because every copy redefines the same registers, a cross-copy
dependency always spans exactly one copy, so the 2-copy dual-writeback DAG
built by :func:`repro.core.analysis.analyze.analyze_kernel` is a complete
template: copy-1's predecessor lists split into *intra* edges (distance 0)
and *cross* edges (distance 1), and copy-0's lists are exactly the intra
subset.  :func:`template_from_dag` extracts this once; the event-driven
sweep then computes, for every replicated node in program order,

``dispatch``
    bounded by program order, the frontend issue width, a free ROB slot
    (FIFO: the slot of the node ``rob_size`` back frees at its retirement),
    a free scheduler slot (a min-heap over occupants' issue times — pop the
    earliest-freeing slot when full), and a free load/store-queue slot
    (FIFO on retirement, loads and stores in separate queues).
``issue``
    when dispatched, all register inputs are complete, and a port from each
    µ-op's eligible set is free; µ-ops greedily take the earliest-available
    eligible port (oldest-first, no backfilling — an age-ordered scheduler).
``complete``
    issue of the last µ-op plus the node's DB latency.
``retire``
    in order, ``retire_width`` per cycle, never before completion.

Per-copy retire-time deltas converge geometrically to the steady-state
cycles per block; the sweep stops at the first stable window.

The per-node state recurrence is inherently sequential, so the inner sweep
is a tight scalar loop; the *static* per-node data (latencies, CSR
predecessor offsets, µ-op port sets), the convergence detection, and the
:func:`simulate_kernels` batch API are NumPy-vectorized.

Bracket closure
---------------
Greedy integral scheduling can only do worse than the fractional min-max
bound, so the measured steady state satisfies ``raw >= TP(balanced)`` up to
convergence tolerance; it can exceed CP when port contention or window
stalls dominate (and for resource-bound kernels ``TP > CP`` makes the
bracket empty).  The headline prediction is therefore clamped into
``[TP, max(TP, CP)]`` — the differential invariant ``TP(balanced) <= sim
<= CP`` holds on every kernel whose bracket is well-formed, and ``sim ==
TP`` on resource-pinned kernels.  The unclamped measurement is kept in
:attr:`SimResult.raw_cy_per_block`.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.analysis.dag import DependencyDAG, build_dag
from repro.core.machine.model import MachineModel, pressure_uops
from repro.core.machine.window import WindowParams

#: |delta_c - delta_{c-1}| below this counts as a converged steady state.
CONVERGENCE_TOL = 1e-9


@dataclass(frozen=True)
class KernelTemplate:
    """Static per-copy node data extracted from a 2-copy dual-writeback DAG."""

    n_nodes: int  # nodes per body copy
    latency: np.ndarray  # (n_nodes,) float64
    # CSR predecessor offsets: intra-copy (distance 0) and cross-copy
    # (distance 1, offsets into the *previous* copy).
    intra_ptr: np.ndarray
    intra_idx: np.ndarray
    cross_ptr: np.ndarray
    cross_idx: np.ndarray
    # Per node: tuple of (cycles, eligible port indices) µ-ops.
    uops: Tuple[Tuple[Tuple[float, Tuple[int, ...]], ...], ...]
    is_load: np.ndarray  # (n_nodes,) bool — occupies a load-queue entry
    is_store: np.ndarray  # (n_nodes,) bool — occupies a store-queue entry
    ports: Tuple[str, ...]


@dataclass(frozen=True)
class SimResult:
    """Steady-state point prediction for one kernel block."""

    cy_per_block: float  # headline prediction, clamped into [TP, max(TP, CP)]
    raw_cy_per_block: float  # unclamped measured steady-state delta
    copies: int  # body copies simulated before convergence (or the cap)
    converged: bool
    clamped_to: str  # "" | "tp" | "cp" — which bracket edge clipped raw
    limiter: str  # dominant binding constraint in the last simulated copy
    window: Optional[WindowParams] = None
    port_busy: Dict[str, float] = None  # type: ignore[assignment]

    def per_iteration(self, unroll: int) -> float:
        return self.cy_per_block / max(unroll, 1)


def _node_uops(node, port_index: Dict[str, int]):
    """Eligible-port µ-ops for one DAG node, as port *indices*.

    Split-load nodes carry the machine's load part; instruction nodes carry
    the primary entry plus any split-store part (stores get no separate DAG
    node).  Writeback address-update nodes and macro-fused-away compares
    occupy frontend/ROB slots but no execution port, matching the throughput
    analysis, which charges them no pressure either.
    """
    cost = node.cost
    if cost is None or node.is_wb or cost.fused_away:
        return ()
    if node.kind == "load":
        entries = (cost.load,)
    else:
        entries = (cost.entry, cost.store)
    uops = []
    for entry in entries:
        if entry is None:
            continue
        for cycles, ports in (entry.uops if entry.uops is not None
                              else pressure_uops(entry.pressure)):
            if cycles <= 0.0 or not ports:
                continue
            uops.append((float(cycles), tuple(port_index[p] for p in ports)))
    return tuple(uops)


def _csr(lists: Sequence[Sequence[int]]) -> Tuple[np.ndarray, np.ndarray]:
    ptr = np.zeros(len(lists) + 1, dtype=np.int64)
    for i, row in enumerate(lists):
        ptr[i + 1] = ptr[i] + len(row)
    idx = np.fromiter((p for row in lists for p in row), dtype=np.int64,
                      count=int(ptr[-1]))
    return ptr, idx


def template_from_dag(dag: DependencyDAG, model: MachineModel) -> KernelTemplate:
    """Extract the replication template from a ``copies=2`` DAG build.

    Uses the default (``preds``) adjacency — the split-writeback view, which
    is the hardware-true µ-op structure.
    """
    total = len(dag.nodes)
    if total % 2 != 0:
        raise ValueError("simulator template needs a copies=2 DAG build")
    n = total // 2
    for j in range(n):  # cheap structural check of copy alignment
        a, b = dag.nodes[j], dag.nodes[n + j]
        if (a.instr_index, a.kind, a.is_wb) != (b.instr_index, b.kind, b.is_wb):
            raise ValueError("DAG copies are not structurally aligned")

    port_index = {p: i for i, p in enumerate(model.ports)}
    intra: List[List[int]] = []
    cross: List[List[int]] = []
    for j in range(n):
        row_i: List[int] = []
        row_c: List[int] = []
        for p in dag.preds[n + j]:
            (row_i if p >= n else row_c).append(p - n if p >= n else p)
        intra.append(row_i)
        cross.append(row_c)
    intra_ptr, intra_idx = _csr(intra)
    cross_ptr, cross_idx = _csr(cross)

    is_load = np.zeros(n, dtype=bool)
    is_store = np.zeros(n, dtype=bool)
    uops = []
    for j in range(n):
        node = dag.nodes[j]
        uops.append(_node_uops(node, port_index))
        cost = node.cost
        if cost is not None and not node.is_wb:
            if node.kind == "load":
                is_load[j] = True
            else:
                if cost.form.loads and cost.load is None:
                    is_load[j] = True  # pure load: the instr is the access
                if cost.form.stores:
                    is_store[j] = True
    return KernelTemplate(
        n_nodes=n,
        latency=np.array([dag.nodes[j].latency for j in range(n)],
                         dtype=np.float64),
        intra_ptr=intra_ptr, intra_idx=intra_idx,
        cross_ptr=cross_ptr, cross_idx=cross_idx,
        uops=tuple(uops), is_load=is_load, is_store=is_store,
        ports=tuple(model.ports),
    )


def _classify(d_terms: Dict[str, float], dispatch: float, ready: float,
              exec_start: float) -> str:
    if exec_start > max(dispatch, ready):
        return "ports"
    if ready > dispatch:
        return "dependencies"
    # Dispatch-bound: name a window constraint only if it was binding.
    for name, t in d_terms.items():
        if t == dispatch and name != "frontend":
            return name
    return "frontend"


def simulate_template(
    template: KernelTemplate,
    window: WindowParams,
    *,
    max_copies: int = 48,
    warmup_copies: int = 2,
    tol: float = CONVERGENCE_TOL,
    cancel: Optional[Callable[[], None]] = None,
) -> Tuple[float, int, bool, str, Dict[str, float]]:
    """Run the sweep; returns ``(cy/block, copies, converged, limiter,
    port_busy)``."""
    n = template.n_nodes
    if n == 0:
        return 0.0, 0, True, "", {}
    lat = template.latency.tolist()
    ip, ii = template.intra_ptr.tolist(), template.intra_idx.tolist()
    cp_, ci = template.cross_ptr.tolist(), template.cross_idx.tolist()
    uops = template.uops
    is_load = template.is_load.tolist()
    is_store = template.is_store.tolist()
    width = window.issue_width
    rob = window.rob_size
    retire_w = window.retire_width
    lsq = window.lsq_size

    disp: List[float] = []
    comp: List[float] = []
    ret: List[float] = []
    sched_heap: List[float] = []
    sched_cap = window.sched_size
    lq: List[int] = []  # global ids of load-queue occupants, dispatch order
    sq: List[int] = []
    port_free = [0.0] * len(template.ports)
    port_busy = [0.0] * len(template.ports)

    deltas = np.zeros(max_copies, dtype=np.float64)
    copies = 0
    converged = False
    limiter_votes: Dict[str, int] = {}
    cy_block = 0.0
    # Bodies narrower than the frontend/retire width retire several copies
    # per cycle, so per-copy retire deltas are *periodic* (e.g. 0,0,0,1 for
    # a 1-µ-op body on a width-4 machine), not constant.  Convergence must
    # therefore compare span-aligned windowed means; span degenerates to 1
    # (plain adjacent deltas) whenever the body fills the machine width.
    span = max(1, -(-width // n), -(-retire_w // n))

    for c in range(max_copies):
        if cancel is not None:
            cancel()
        base = c * n
        if c == max_copies - 1 or c >= warmup_copies:
            limiter_votes = {}
        for p in range(len(port_busy)):
            port_busy[p] = 0.0
        for j in range(n):
            k = base + j
            # -- dispatch ---------------------------------------------------
            d_terms: Dict[str, float] = {}
            d = disp[k - 1] if k else 0.0
            if k >= width:
                d_terms["frontend"] = disp[k - width] + 1.0
            if k >= rob:
                d_terms["rob"] = ret[k - rob]
            if is_load[j]:
                lq.append(k)
                if len(lq) > lsq:
                    d_terms["lsq"] = ret[lq[-1 - lsq]]
            if is_store[j]:
                sq.append(k)
                if len(sq) > lsq:
                    d_terms["lsq"] = max(d_terms.get("lsq", 0.0),
                                         ret[sq[-1 - lsq]])
            if len(sched_heap) >= sched_cap:
                d_terms["scheduler"] = heapq.heappop(sched_heap)
            for t in d_terms.values():
                if t > d:
                    d = t
            # -- ready ------------------------------------------------------
            r = 0.0
            for q in range(ip[j], ip[j + 1]):
                t = comp[base + ii[q]]
                if t > r:
                    r = t
            if c:
                prev = base - n
                for q in range(cp_[j], cp_[j + 1]):
                    t = comp[prev + ci[q]]
                    if t > r:
                        r = t
            t0 = d if d > r else r
            # -- issue: greedy earliest eligible port -----------------------
            exec_start = t0
            for cycles, ports in uops[j]:
                best_p = ports[0]
                best_t = port_free[best_p]
                if len(ports) > 1:
                    for p in ports[1:]:
                        t = port_free[p]
                        if t < best_t:
                            best_t, best_p = t, p
                        if t <= t0:
                            break
                start = best_t if best_t > t0 else t0
                port_free[best_p] = start + cycles
                port_busy[best_p] += cycles
                if start > exec_start:
                    exec_start = start
            heapq.heappush(sched_heap, exec_start)
            comp.append(exec_start + lat[j])
            # -- retire -----------------------------------------------------
            t = comp[k]
            if k and ret[k - 1] > t:
                t = ret[k - 1]
            if k >= retire_w and ret[k - retire_w] + 1.0 > t:
                t = ret[k - retire_w] + 1.0
            ret.append(t)
            disp.append(d)
            if c >= warmup_copies:
                label = _classify(d_terms, d, r, exec_start)
                limiter_votes[label] = limiter_votes.get(label, 0) + 1
        copies = c + 1
        if c == 0:
            deltas[0] = ret[-1]
        else:
            deltas[c] = ret[-1] - ret[base - 1]
        if c >= warmup_copies + 2 * span - 1:
            last = deltas[c - span + 1:c + 1]
            prev = deltas[c - 2 * span + 1:c - span + 1]
            if abs(float(last.mean()) - float(prev.mean())) <= tol:
                cy_block = float(last.mean())
                converged = True
                break
            if c >= warmup_copies + 4 * span - 1:
                # Period-2 oscillation on top of the span: accept a stable
                # double-width windowed mean.
                w4 = deltas[c - 4 * span + 1:c + 1]
                half = 2 * span
                if abs(float(w4[:half].mean()) -
                       float(w4[half:].mean())) <= max(tol, 1e-6):
                    cy_block = float(w4.mean())
                    converged = True
                    break
    if not converged:
        tail = deltas[max(copies - 8, 1):copies]
        cy_block = float(tail.mean()) if tail.size else float(deltas[0])
    limiter = max(limiter_votes, key=limiter_votes.get) if limiter_votes else ""
    busy = {template.ports[p]: port_busy[p]
            for p in range(len(port_busy)) if port_busy[p] > 0.0}
    return cy_block, copies, converged, limiter, busy


def simulate_from_dag(
    dag: DependencyDAG,
    model: MachineModel,
    *,
    window: Optional[WindowParams] = None,
    tp_block: Optional[float] = None,
    cp_block: Optional[float] = None,
    max_copies: int = 48,
    cancel: Optional[Callable[[], None]] = None,
) -> SimResult:
    """Simulate a kernel from its 2-copy DAG and clamp into the bracket.

    ``tp_block``/``cp_block`` are the balanced-throughput and critical-path
    predictions in cycles per *block* (not per iteration); either may be
    ``None``, in which case that side of the clamp is skipped.
    """
    params = window if window is not None else model.window
    if params is None:
        raise ValueError(f"machine '{model.name}' has no window parameters; "
                         f"pass window= explicitly")
    template = template_from_dag(dag, model)
    raw, copies, converged, limiter, busy = simulate_template(
        template, params, max_copies=max_copies, cancel=cancel)
    value = raw
    clamped = ""
    if tp_block is not None and value < tp_block:
        value = tp_block
        clamped = "tp"
    ceiling = cp_block
    if ceiling is not None and tp_block is not None and tp_block > ceiling:
        ceiling = tp_block  # resource-pinned kernel: empty bracket
    if ceiling is not None and value > ceiling:
        value = ceiling
        clamped = "cp"
    return SimResult(cy_per_block=value, raw_cy_per_block=raw, copies=copies,
                     converged=converged, clamped_to=clamped, limiter=limiter,
                     window=params, port_busy=busy)


def simulate_kernel(kernel, model: MachineModel, *,
                    window: Optional[WindowParams] = None,
                    max_copies: int = 48) -> SimResult:
    """Standalone entry point: resolve, build the DAG, bracket, simulate."""
    from repro.core.analysis.critical_path import critical_path_from_dag
    from repro.core.analysis.throughput import throughput_from_costs

    costs = model.resolve_kernel(kernel)
    tp = throughput_from_costs(costs, model)
    dag = build_dag(kernel, model, copies=2, costs=costs, dual_writeback=True)
    cp = critical_path_from_dag(dag)
    return simulate_from_dag(dag, model, window=window,
                             tp_block=tp.balanced_throughput,
                             cp_block=cp.length, max_copies=max_copies)


def simulate_kernels(kernels, model: MachineModel, *,
                     window: Optional[WindowParams] = None,
                     max_copies: int = 48) -> List[SimResult]:
    """Batched convenience wrapper over :func:`simulate_kernel`."""
    return [simulate_kernel(k, model, window=window, max_copies=max_copies)
            for k in kernels]
