"""Window-limited out-of-order point-prediction simulator.

Closes the paper's ``[TP, CP]`` bracket with a steady-state cycles-per-
iteration prediction; see :mod:`repro.core.sim.engine` for the model and
:class:`repro.core.machine.window.WindowParams` for the per-arch window
capacities it consumes.
"""

from repro.core.machine.window import WindowParams
from repro.core.sim.engine import (KernelTemplate, SimResult,
                                   simulate_from_dag, simulate_kernel,
                                   simulate_kernels, simulate_template,
                                   template_from_dag)

__all__ = [
    "KernelTemplate",
    "SimResult",
    "WindowParams",
    "simulate_from_dag",
    "simulate_kernel",
    "simulate_kernels",
    "simulate_template",
    "template_from_dag",
]
