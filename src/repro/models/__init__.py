from repro.models.transformer import (
    decode_step,
    forward_train,
    init_cache,
    init_params,
    prefill,
)

__all__ = ["decode_step", "forward_train", "init_cache", "init_params", "prefill"]
