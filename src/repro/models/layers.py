"""Shared model layers: norms, rotary embeddings, attention (naive / chunked
online-softmax / decode), and gated MLPs.  Pure functions over param dicts;
activation sharding via ``repro.distributed.constrain``.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain

DATA = ("pod", "data")  # batch axes (sanitized away when mesh lacks "pod")
MODEL = "model"


# ---------------------------------------------------------------------------
# Norms / positions
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def rope_frequencies(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(1e4) / dim))
    table = jnp.zeros((length, dim), jnp.float32)
    table = table.at[:, 0::2].set(jnp.sin(pos * div))
    table = table.at[:, 1::2].set(jnp.cos(pos * div))
    return table


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _group_query(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B,S,H,D) -> (B,S,K,G,D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def naive_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    causal: bool = True, window: int = 0,
    q_offset: int = 0, softcap: float = 0.0,
) -> jnp.ndarray:
    """Materializes the full (S, T) score matrix — the paper-baseline path.

    q: (B,S,H,D); k/v: (B,T,K,D).  Returns (B,S,H,D).
    """
    b, s, h, d = q.shape
    t, n_kv = k.shape[1], k.shape[2]
    qg = _group_query(q, n_kv)
    scale = 1.0 / math.sqrt(d)
    # Native-dtype operands with f32 accumulation: never materialize an f32
    # copy of K/V (2x HBM) — MXU accumulates in f32 anyway.
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(s) + q_offset
    kpos = jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, d).astype(q.dtype)


def chunked_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    chunk: int = 512, causal: bool = True, window: int = 0,
    q_offset: int = 0, softcap: float = 0.0,
) -> jnp.ndarray:
    """Online-softmax attention over KV chunks (flash-style in XLA).

    Peak memory O(S * chunk) instead of O(S * T); the Pallas kernel
    (`repro.kernels.flash_attention`) is the TPU-tiled version of this
    algorithm and is validated against the same oracle.
    """
    b, s, h, d = q.shape
    t, n_kv = k.shape[1], k.shape[2]
    if t % chunk != 0:
        return naive_attention(q, k, v, causal, window, q_offset, softcap)
    n_chunks = t // chunk
    qg = _group_query(q, n_kv)
    scale = 1.0 / math.sqrt(d)
    qpos = (jnp.arange(s) + q_offset)[:, None]  # (S,1)

    kc = k.reshape(b, n_chunks, chunk, n_kv, d)
    vc = v.reshape(b, n_chunks, chunk, n_kv, d)

    def body(carry, inputs):
        m, l, acc = carry
        j, kj, vj = inputs
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, kj,
                            preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            scores = softcap * jnp.tanh(scores / softcap)
        kpos = j * chunk + jnp.arange(chunk)[None, :]  # (1,chunk)
        # Additive (S, chunk) f32 bias instead of a pred mask + where: the
        # boolean mask gets hoisted/broadcast to full scores shape across
        # all chunk iterations by XLA (hundreds of MB of pred buffers);
        # the bias stays (S, chunk) and fuses into the add (§Perf iter 7).
        bias = jnp.zeros((s, chunk), jnp.float32)
        if causal:
            bias = jnp.where(kpos <= qpos, bias, -1e30)
        if window > 0:
            bias = jnp.where(kpos > qpos - window, bias, -1e30)
        scores = scores + bias[None, None, None]
        m_j = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m, m_j)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    n_g = h // n_kv
    m0 = jnp.full((b, n_kv, n_g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, n_kv, n_g, s), jnp.float32)
    acc0 = jnp.zeros((b, n_kv, n_g, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1)  # (b,s,k,g,d)
    return out.reshape(b, s, h, d).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
    lengths: jnp.ndarray, window: int = 0, softcap: float = 0.0,
) -> jnp.ndarray:
    """Single-position attention against a (B,T,K,D) cache.

    q: (B,1,H,D); lengths: (B,) number of valid cache positions (inclusive of
    the current token).  Memory O(T) — the XLA counterpart of flash-decode.
    """
    b, _, h, d = q.shape
    t, n_kv = k_cache.shape[1], k_cache.shape[2]
    qg = _group_query(q, n_kv)[:, 0].astype(k_cache.dtype)  # (B,K,G,D)
    scale = 1.0 / math.sqrt(d)
    # Cache stays in its storage dtype; f32 accumulation via the MXU.  An
    # .astype(f32) here would materialize a second full-cache-sized buffer.
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    kpos = jnp.arange(t)[None, :]  # (1,T)
    valid = kpos < lengths[:, None]
    if window > 0:
        valid &= kpos >= jnp.maximum(lengths[:, None] - window, 0)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projection + rope + qk-norm wrapper)
# ---------------------------------------------------------------------------


def attention_block(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cfg,
    run,
    positions: jnp.ndarray,
    kv_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    cache_fill: Optional[jnp.ndarray] = None,
    causal: bool = True,
    kv_x: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    """Returns (output, new_kv).

    * prefill/train: ``new_kv`` is this segment's rope'd (K, V) — the caller
      may install it as the cache.
    * decode (``kv_cache`` + scalar ``cache_pos`` given): the new token's K/V
      is written into the cache at ``cache_pos`` and ``new_kv`` is the
      updated cache.
    * ``kv_x`` selects cross-attention (encoder output as KV source, no rope).
    """
    b, s, _ = x.shape
    h, k_heads, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    kv_src = x if kv_x is None else kv_x

    q = (x @ params["wq"]).reshape(b, s, h, d)
    kk = (kv_src @ params["wk"]).reshape(b, kv_src.shape[1], k_heads, d)
    vv = (kv_src @ params["wv"]).reshape(b, kv_src.shape[1], k_heads, d)
    q = constrain(q, DATA, None, MODEL, None)
    kk = constrain(kk, DATA, None, MODEL, None)
    vv = constrain(vv, DATA, None, MODEL, None)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        kk = rms_norm(kk, params["k_norm"], cfg.norm_eps)
    if use_rope and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        kk = apply_rope(kk, positions, cfg.rope_theta)

    if kv_cache is not None:
        k_cache, v_cache = kv_cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, kk.astype(k_cache.dtype), cache_pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, vv.astype(v_cache.dtype), cache_pos, axis=1)
        fill = cache_fill if cache_fill is not None else cache_pos + s
        lengths = jnp.full((b,), fill, dtype=jnp.int32)
        # Ring-buffer caches (windowed attention) index positions modulo the
        # buffer, so the window re-mask inside decode_attention must be off
        # (every live slot is in-window by construction).
        win = 0 if cache_fill is not None else cfg.window
        out = decode_attention(q, k_cache, v_cache, lengths,
                               window=win, softcap=cfg.attn_logit_softcap)
        new_kv = (k_cache, v_cache)
    else:
        if run.attention_impl == "naive":
            out = naive_attention(q, kk, vv, causal=causal, window=cfg.window,
                                  softcap=cfg.attn_logit_softcap)
        else:
            out = chunked_attention(q, kk, vv, chunk=run.attention_chunk,
                                    causal=causal, window=cfg.window,
                                    softcap=cfg.attn_logit_softcap)
        new_kv = (kk, vv)
    out = constrain(out, DATA, None, MODEL, None)
    y = out.reshape(b, s, h * d) @ params["wo"]
    return constrain(y, DATA, None, None), new_kv


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_block(params: Dict[str, jnp.ndarray], x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        gate_up = x @ params["wi"]  # (..., 2*ff)
        gate_up = constrain(gate_up, DATA, None, MODEL)
        gate, up = jnp.split(gate_up, 2, axis=-1)
        hidden = jax.nn.silu(gate) * up
    else:
        hidden = jax.nn.gelu(x @ params["wi"])
        hidden = constrain(hidden, DATA, None, MODEL)
    y = hidden @ params["wo"]
    return constrain(y, DATA, None, None)
