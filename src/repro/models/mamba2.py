"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Training/prefill uses the chunked dual form: quadratic attention-like
computation inside chunks of length Q plus a sequential inter-chunk state
recurrence — the loop-carried dependency the HLO LCD analysis surfaces.
Decode is the O(1)-state recurrence.  The intra-chunk computation has a
Pallas kernel counterpart (`repro.kernels.ssd_scan`) validated against this
reference.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models.layers import DATA, MODEL, rms_norm


def init_mamba_params(key, cfg, layer_count, dtype) -> Dict[str, jnp.ndarray]:
    """Stacked Mamba-2 block params with leading ``layer_count`` dims."""
    d, di, n, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    keys = jax.random.split(key, 6)
    scale = 0.02
    proj_out = 2 * di + 2 * n + nh  # z, x, B, C, dt
    return {
        "in_proj": jax.random.normal(keys[0], (*layer_count, d, proj_out), dtype) * scale,
        "conv_w": jax.random.normal(keys[1], (*layer_count, cfg.ssm_conv, conv_ch), dtype) * scale,
        "A_log": jnp.zeros((*layer_count, nh), dtype),
        "D": jnp.ones((*layer_count, nh), dtype),
        "dt_bias": jnp.zeros((*layer_count, nh), dtype),
        "ssm_norm": jnp.ones((*layer_count, di), dtype),
        "out_proj": jax.random.normal(keys[2], (*layer_count, di, d), dtype) * scale,
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d via shifted adds.  x: (B,S,C); w: (K,C).

    ``state``: (B, K-1, C) trailing context from the previous segment.
    Returns (y, new_state)."""
    k = w.shape[0]
    b, s, c = x.shape
    if state is None:
        state = jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    y = jnp.zeros_like(x)
    for i in range(k):
        y = y + xp[:, i:i + s, :] * w[i]
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return jax.nn.silu(y), new_state


def _split_proj(proj: jnp.ndarray, cfg):
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., -nh:]
    return z, xbc, dt


def ssd_chunked(
    x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
    Bm: jnp.ndarray, Cm: jnp.ndarray,
    chunk: int, h0: Optional[jnp.ndarray] = None,
    head_block: int = 4,
    chunk_shard: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan (reference; pure jnp).

    x: (B,S,H,P)  dt: (B,S,H)  A: (H,)  Bm/Cm: (B,S,N)
    h0: optional initial state (B,H,N,P).
    Returns (y (B,S,H,P), final state (B,H,N,P)).

    The per-head decay tensor (B,NC,Q,Q,H) is the memory hot-spot of the
    dual form; heads are processed in blocks of ``head_block`` (mirroring the
    Pallas kernel's per-head grid) so the peak is (B,NC,Q,Q,head_block).
    """
    b, s, nh, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    if s % q != 0:
        # Right-pad to a chunk multiple: dt=0 there => decay 1, contribution
        # 0, so the final state equals the state after the s real steps.
        pad = q - s % q
        y, h_last = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(Bm, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(Cm, ((0, 0), (0, pad), (0, 0))),
            chunk, h0, head_block, chunk_shard,
        )
        return y[:, :s], h_last
    nc = s // q

    xc = x.reshape(b, nc, q, nh, p)
    dtc = dt.reshape(b, nc, q, nh).astype(jnp.float32)
    bc = Bm.reshape(b, nc, q, n)
    cc = Cm.reshape(b, nc, q, n)

    dA = dtc * A.astype(jnp.float32)  # (B,nc,Q,H), negative
    cum = jnp.cumsum(dA, axis=2)  # inclusive cumulative log-decay
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # (B,nc,Q,H,P) f32

    if chunk_shard:
        # The intra-chunk dual form is chunk-parallel: shard the chunk dim
        # over the model axis so the (Q,Q,head) decay tensors divide by it.
        cum = constrain(cum, DATA, MODEL, None, None)
        xdt = constrain(xdt, DATA, MODEL, None, None, None)
        bc = constrain(bc, DATA, MODEL, None, None)
        cc = constrain(cc, DATA, MODEL, None, None)

    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc,
                        preferred_element_type=jnp.float32)  # (B,nc,Q,Q)
    if chunk_shard:
        scores = constrain(scores, DATA, MODEL, None, None)
    tri = jnp.tril(jnp.ones((q, q), jnp.float32))

    hb = 1
    for cand in range(min(head_block, nh), 0, -1):
        if nh % cand == 0:
            hb = cand
            break
    nb = nh // hb
    cum_b = jnp.moveaxis(cum.reshape(b, nc, q, nb, hb), 3, 0)  # (nb,b,nc,Q,hb)
    xdt_b = jnp.moveaxis(xdt.reshape(b, nc, q, nb, hb, p), 3, 0)

    def per_block(args):
        cum_h, xdt_h = args  # (b,nc,Q,hb), (b,nc,Q,hb,p)
        # Mask the exponent BEFORE exp (double-where): the upper triangle has
        # cum_i - cum_j > 0 growing with chunk length, so exp() overflows to
        # inf there and inf * tri(=0) poisons fwd/bwd with NaNs.
        diff = cum_h[:, :, :, None, :] - cum_h[:, :, None, :, :]
        valid = tri[None, None, :, :, None] > 0
        decay = jnp.where(valid, jnp.exp(jnp.where(valid, diff, 0.0)), 0.0)
        m = scores[..., None] * decay
        y = jnp.einsum("bcijh,bcjhp->bcihp", m, xdt_h)
        d2e = jnp.exp(cum_h[:, :, -1:, :] - cum_h)  # (b,nc,Q,hb)
        st = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc.astype(jnp.float32),
                        d2e, xdt_h)
        return y, st

    y_b, st_b = jax.lax.map(per_block, (cum_b, xdt_b))
    y_intra = jnp.moveaxis(y_b, 0, 3).reshape(b, nc, q, nh, p)
    chunk_states = jnp.moveaxis(st_b, 0, 2).reshape(b, nc, nh, n, p)
    if chunk_shard:
        y_intra = constrain(y_intra, DATA, MODEL, None, None, None)
        chunk_states = constrain(chunk_states, DATA, MODEL, None, None, None)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    def body(h_prev, inputs):
        cdecay, cstate = inputs  # (B,H), (B,H,N,P)
        h_new = cdecay[..., None, None] * h_prev + cstate
        return h_new, h_prev

    h_init = (jnp.zeros((b, nh, n, p), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, h_prevs = jax.lax.scan(
        body, h_init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(chunk_states, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", cc.astype(jnp.float32),
                         jnp.exp(cum), h_prevs)
    y = (y_intra + y_inter).reshape(b, s, nh, p)
    return y.astype(x.dtype), h_last.astype(x.dtype)


def mamba_block(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cfg,
    ssm_state: Optional[jnp.ndarray] = None,
    conv_state: Optional[jnp.ndarray] = None,
    single_step: bool = False,
    chunk_shard: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Mamba-2 block.  x: (B,S,d) -> (y, ssm_state, conv_state).

    ``single_step=True`` runs the O(1) decode recurrence (S must be 1).
    ``chunk_shard`` keeps the whole block sequence-sharded over the model
    axis (in_proj/conv activations divide by it; the causal conv's halo
    exchange becomes a collective-permute) — §Perf iterations 1 & 5.
    """
    b, s, _ = x.shape
    di, n, nh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    proj = x @ params["in_proj"]
    if chunk_shard and not single_step:
        proj = constrain(proj, DATA, MODEL, None)
    else:
        proj = constrain(proj, DATA, None, MODEL)
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], conv_state)
    xs = xbc[..., :di].reshape(b, s, nh, p)
    Bm = xbc[..., di:di + n]
    Cm = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    if single_step:
        dA = jnp.exp(dt[:, 0] * A)  # (B,H)
        h_prev = (jnp.zeros((b, nh, n, p), jnp.float32) if ssm_state is None
                  else ssm_state.astype(jnp.float32))
        xdt = xs[:, 0].astype(jnp.float32) * dt[:, 0][..., None]  # (B,H,P)
        h_new = dA[..., None, None] * h_prev + jnp.einsum(
            "bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32), xdt)
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), h_new)
        y = y[:, None].astype(x.dtype)  # (B,1,H,P)
        ssm_state = h_new.astype(x.dtype)
    else:
        y, ssm_state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk, ssm_state,
                                   chunk_shard=chunk_shard)

    y = y + params["D"].astype(x.dtype)[None, None, :, None] * xs
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), params["ssm_norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return constrain(out, DATA, None, None), ssm_state, conv_state
