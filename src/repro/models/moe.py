"""Mixture-of-Experts FFN: GShard-style grouped top-k dispatch with capacity.

Tokens are split into groups (``moe_group_size``); each group routes
independently with per-group expert capacity C = ceil(top_k * S_g * cf / E).
Dispatch/combine are einsums so GSPMD can shard them: groups over the data
axes, experts over the model axis (expert parallelism) — the group->expert
resharding is the all-to-all the roofline's ICI term sees.

Supports DeepSeek-MoE fine-grained routing (64 routed top-6 + 2 shared
experts) and Phi-3.5-MoE (16 routed top-2).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models.layers import DATA, MODEL


def _swiglu(x: jnp.ndarray, wi: jnp.ndarray, wo: jnp.ndarray) -> jnp.ndarray:
    gate_up = jnp.einsum("...d,df->...f", x, wi)
    gate, up = jnp.split(gate_up, 2, axis=-1)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(gate) * up, wo)


def route_topk(
    logits: jnp.ndarray, top_k: int, capacity: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k routing with per-group capacity.

    logits: (G, S, E).  Returns (dispatch (G,S,E,C) bool-ish float,
    combine (G,S,E,C) float, aux_loss scalar).
    """
    g, s, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topk_probs, topk_idx = jax.lax.top_k(probs, top_k)  # (G,S,k)
    topk_probs = topk_probs / jnp.maximum(
        jnp.sum(topk_probs, axis=-1, keepdims=True), 1e-9)

    # Position of each (token, k-slot) within its expert's queue, computed
    # slot-major so earlier tokens win capacity (GShard semantics).
    onehot = jax.nn.one_hot(topk_idx, e, dtype=jnp.float32)  # (G,S,k,E)
    slot_major = jnp.swapaxes(onehot, 1, 2).reshape(g, top_k * s, e)
    positions = jnp.cumsum(slot_major, axis=1) - slot_major  # (G,k*S,E)
    positions = jnp.swapaxes(positions.reshape(g, top_k, s, e), 1, 2)  # (G,S,k,E)
    pos_in_expert = jnp.sum(positions * onehot, axis=-1)  # (G,S,k)
    keep = pos_in_expert < capacity

    # aux load-balancing loss (Switch-style): E * mean(frac_tokens * frac_probs)
    token_frac = jnp.mean(jnp.sum(onehot, axis=2), axis=1)  # (G,E)
    prob_frac = jnp.mean(probs, axis=1)  # (G,E)
    aux = e * jnp.mean(jnp.sum(token_frac * prob_frac, axis=-1))

    pos_oh = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), capacity,
                            dtype=jnp.float32) * keep[..., None]  # (G,S,k,C)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot, pos_oh)
    combine = jnp.einsum("gsk,gske,gskc->gsec", topk_probs, onehot, pos_oh)
    return dispatch, combine, aux


def route_topk_indices(
    logits: jnp.ndarray, top_k: int, capacity: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Index-based routing (the gather-dispatch path).

    Returns (topk_idx (G,S,k), gates (G,S,k), pos (G,S,k), keep (G,S,k),
    aux) — same semantics as :func:`route_topk` without materializing the
    (G,S,E,C) dispatch tensors.
    """
    g, s, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topk_probs, topk_idx = jax.lax.top_k(probs, top_k)
    gates = topk_probs / jnp.maximum(
        jnp.sum(topk_probs, axis=-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(topk_idx, e, dtype=jnp.float32)  # (G,S,k,E)
    slot_major = jnp.swapaxes(onehot, 1, 2).reshape(g, top_k * s, e)
    positions = jnp.cumsum(slot_major, axis=1) - slot_major
    positions = jnp.swapaxes(positions.reshape(g, top_k, s, e), 1, 2)
    pos_in_expert = jnp.sum(positions * onehot, axis=-1).astype(jnp.int32)
    keep = pos_in_expert < capacity

    token_frac = jnp.mean(jnp.sum(onehot, axis=2), axis=1)
    prob_frac = jnp.mean(probs, axis=1)
    aux = e * jnp.mean(jnp.sum(token_frac * prob_frac, axis=-1))
    return topk_idx, gates, pos_in_expert, keep, aux


def _moe_gather_dispatch(params, xg, cfg, capacity):
    """Gather/scatter dispatch: no dense (G,S,E,C) one-hot matmuls.

    FLOPs ~ expert GEMMs only; dispatch/combine are index ops (§Perf
    iteration: the einsum dispatch costs T*topk*cf*S_g*d MACs — an order of
    magnitude more than the expert compute for small-capacity MoE).
    """
    g, s, d = xg.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    logits = jnp.einsum("gsd,de->gse", xg, params["router"])
    topk_idx, gates, pos, keep, aux = route_topk_indices(logits, k, capacity)

    # Scatter token ids into (G, E, C+1) slot table (overflow -> slot C).
    slot_token = jnp.zeros((g, e, capacity + 1), jnp.int32)
    slot_fill = jnp.zeros((g, e, capacity + 1), xg.dtype)
    gi = jnp.arange(g)[:, None, None]
    si = jnp.broadcast_to(jnp.arange(s)[None, :, None], (g, s, k))
    pos_c = jnp.where(keep, pos, capacity)
    slot_token = slot_token.at[gi, topk_idx, pos_c].set(si, mode="drop")
    slot_fill = slot_fill.at[gi, topk_idx, pos_c].set(1.0, mode="drop")
    slot_token = slot_token[..., :capacity]  # (G,E,C)
    slot_fill = slot_fill[..., :capacity]

    # Gather tokens into expert slots: (G,E,C,d), then expert-shard.
    expert_in = jnp.take_along_axis(
        xg[:, None, :, :], slot_token[..., None], axis=2)
    expert_in = expert_in * slot_fill[..., None]
    expert_in = jnp.swapaxes(expert_in, 0, 1)  # (E,G,C,d)
    # Experts over model, groups over data: without the DATA entry every
    # data shard replicates the full expert GEMM (16x redundant compute --
    # found by the per-op FLOP profile, Perf iteration 3).
    expert_in = constrain(expert_in, MODEL, DATA, None, None)

    gate_up = jnp.einsum("egcd,edf->egcf", expert_in, params["moe_wi"])
    gate, up = jnp.split(gate_up, 2, axis=-1)
    expert_out = jnp.einsum("egcf,efd->egcd", jax.nn.silu(gate) * up,
                            params["moe_wo"])
    expert_out = constrain(expert_out, MODEL, DATA, None, None)
    expert_out = jnp.swapaxes(expert_out, 0, 1)  # (G,E,C,d)

    # Combine: per (token, k-slot) gather from its expert slot.
    flat = expert_out.reshape(g, e * capacity, d)
    slot_of_token = topk_idx * capacity + jnp.minimum(pos, capacity - 1)
    picked = jnp.take_along_axis(
        flat[:, None, :, :],
        slot_of_token.transpose(0, 2, 1)[..., None], axis=2)  # (G,k,S,d)
    picked = picked.transpose(0, 2, 1, 3)  # (G,S,k,d)
    w = (gates * keep).astype(xg.dtype)  # dropped slots contribute zero
    yg = jnp.einsum("gsk,gskd->gsd", w, picked)
    return yg, aux


def moe_block(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cfg,
    dispatch_mode: str = "einsum",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (B, S, d), aux loss.  Shared experts run densely."""
    b, s, d = x.shape
    e = cfg.moe_experts
    group = min(cfg.moe_group_size, b * s)
    while (b * s) % group != 0:  # largest group size dividing the token count
        group -= 1
    n_groups = (b * s) // group
    xg = x.reshape(n_groups, group, d)
    xg = constrain(xg, DATA, None, None)

    capacity = max(int(math.ceil(cfg.moe_top_k * group * cfg.moe_capacity_factor / e)), 1)

    if dispatch_mode == "gather":
        yg, aux = _moe_gather_dispatch(params, xg, cfg, capacity)
    else:
        logits = jnp.einsum("gsd,de->gse", xg, params["router"])
        dispatch, combine, aux = route_topk(logits, cfg.moe_top_k, capacity)
        dispatch = constrain(dispatch.astype(x.dtype), DATA, None, MODEL, None)
        combine = constrain(combine.astype(x.dtype), DATA, None, MODEL, None)

        # Dispatch: group-sharded tokens -> expert-sharded slots (all-to-all).
        expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
        expert_in = constrain(expert_in, MODEL, DATA, None, None)

        gate_up = jnp.einsum("egcd,edf->egcf", expert_in, params["moe_wi"])
        gate, up = jnp.split(gate_up, 2, axis=-1)
        expert_out = jnp.einsum("egcf,efd->egcd", jax.nn.silu(gate) * up,
                                params["moe_wo"])
        expert_out = constrain(expert_out, MODEL, DATA, None, None)

        yg = jnp.einsum("gsec,egcd->gsd", combine, expert_out)
    y = yg.reshape(b, s, d)

    if cfg.moe_shared > 0:
        y = y + _swiglu(x, params["shared_wi"], params["shared_wo"])
    return constrain(y, DATA, None, None), aux


def init_moe_params(key, cfg, layer_count: int, dtype) -> Dict[str, jnp.ndarray]:
    """Stacked-over-layers MoE parameters: leading dim = layer_count."""
    d, e = cfg.d_model, cfg.moe_experts
    ffe = cfg.moe_d_ff or cfg.d_ff
    keys = jax.random.split(key, 5)
    scale = 0.02
    out = {
        "router": jax.random.normal(keys[0], (layer_count, d, e), dtype) * scale,
        "moe_wi": jax.random.normal(keys[1], (layer_count, e, d, 2 * ffe), dtype) * scale,
        "moe_wo": jax.random.normal(keys[2], (layer_count, e, ffe, d), dtype) * scale,
    }
    if cfg.moe_shared > 0:
        fsh = cfg.moe_shared * ffe
        out["shared_wi"] = jax.random.normal(keys[3], (layer_count, d, 2 * fsh), dtype) * scale
        out["shared_wo"] = jax.random.normal(keys[4], (layer_count, fsh, d), dtype) * scale
    return out
