"""Model assembly for all assigned architecture families.

Layers are **stacked** (leading L dim) and iterated with ``jax.lax.scan`` so
the HLO stays compact for 512-partition SPMD compiles; remat policies wrap
the scan body.  Families:

  dense   — pre-norm GQA transformer (yi, tinyllama, starcoder2, qwen3)
  moe     — dense attention + GShard MoE FFN (deepseek-moe, phi3.5-moe),
            optional leading dense-FFN layers (DeepSeek layer 0)
  ssm     — Mamba-2 SSD stack (mamba2-130m)
  hybrid  — Mamba-2 backbone + one shared attention block every k layers
            (zamba2), concat(x, embed0) input per Zamba design
  audio   — Whisper-style encoder/decoder backbone, stub frame embeddings
  vlm     — dense backbone with stub patch embeddings prepended (phi3-vision)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed import constrain
from repro.models.layers import (
    DATA, MODEL, attention_block, decode_attention, mlp_block, rms_norm,
    sinusoidal_positions,
)
from repro.models.mamba2 import init_mamba_params, mamba_block
from repro.models.moe import init_moe_params, moe_block

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _init_attn(key, cfg, layer_count, dtype, d_in=None) -> Params:
    d = d_in or cfg.d_model
    hq, hkv = cfg.n_heads * cfg.d_head, cfg.n_kv_heads * cfg.d_head
    ks = jax.random.split(key, 4)
    s = 0.02
    p = {
        "wq": jax.random.normal(ks[0], (*layer_count, d, hq), dtype) * s,
        "wk": jax.random.normal(ks[1], (*layer_count, d, hkv), dtype) * s,
        "wv": jax.random.normal(ks[2], (*layer_count, d, hkv), dtype) * s,
        "wo": jax.random.normal(ks[3], (*layer_count, hq, cfg.d_model), dtype) * s,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((*layer_count, cfg.d_head), dtype)
        p["k_norm"] = jnp.ones((*layer_count, cfg.d_head), dtype)
    return p


def _init_mlp(key, cfg, layer_count, dtype, d_ff=None, d_in=None) -> Params:
    d = d_in or cfg.d_model
    ff = d_ff or cfg.d_ff
    width = 2 * ff if cfg.act == "swiglu" else ff
    k1, k2 = jax.random.split(key)
    s = 0.02
    return {
        "wi": jax.random.normal(k1, (*layer_count, d, width), dtype) * s,
        "wo": jax.random.normal(k2, (*layer_count, ff, cfg.d_model), dtype) * s,
    }


def _init_dense_block(key, cfg, layer_count, dtype) -> Params:
    ka, km = jax.random.split(key)
    return {
        "attn": _init_attn(ka, cfg, layer_count, dtype),
        "mlp": _init_mlp(km, cfg, layer_count, dtype),
        "norm1": jnp.ones((*layer_count, cfg.d_model), dtype),
        "norm2": jnp.ones((*layer_count, cfg.d_model), dtype),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": jax.random.normal(
            keys[0], (cfg.padded_vocab, cfg.d_model), dtype) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.padded_vocab), dtype) * 0.02

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = _init_dense_block(keys[2], cfg, (cfg.n_layers,), dtype)
    elif fam == "moe":
        n_moe = cfg.n_layers - cfg.moe_first_dense
        params["layers"] = {
            "attn": _init_attn(keys[2], cfg, (n_moe,), dtype),
            "moe": init_moe_params(keys[3], cfg, n_moe, dtype),
            "norm1": jnp.ones((n_moe, cfg.d_model), dtype),
            "norm2": jnp.ones((n_moe, cfg.d_model), dtype),
        }
        if cfg.moe_first_dense:
            params["dense_layers"] = _init_dense_block(
                keys[4], cfg, (cfg.moe_first_dense,), dtype)
    elif fam == "ssm":
        params["layers"] = {
            "mamba": init_mamba_params(keys[2], cfg, (cfg.n_layers,), dtype),
            "norm1": jnp.ones((cfg.n_layers, cfg.d_model), dtype),
        }
    elif fam == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        params["layers"] = {
            "mamba": init_mamba_params(keys[2], cfg, (n_groups, every), dtype),
            "norm1": jnp.ones((n_groups, every, cfg.d_model), dtype),
        }
        d2 = 2 * cfg.d_model
        params["shared_attn"] = _init_attn(keys[3], cfg, (), dtype, d_in=d2)
        params["shared_mlp"] = _init_mlp(keys[4], cfg, (), dtype, d_in=d2)
        params["shared_norm1"] = jnp.ones((d2,), dtype)
        params["shared_norm2"] = jnp.ones((d2,), dtype)
        params["inv_proj"] = jax.random.normal(
            keys[5], (n_groups, cfg.d_model, cfg.d_model), dtype) * 0.02
    elif fam == "audio":
        params["enc_layers"] = _init_dense_block(
            keys[2], cfg, (cfg.n_encoder_layers,), dtype)
        dec = _init_dense_block(keys[3], cfg, (cfg.n_layers,), dtype)
        ca = _init_attn(keys[4], cfg, (cfg.n_layers,), dtype)
        dec["cross"] = {"cross_wq": ca["wq"], "cross_wk": ca["wk"],
                        "cross_wv": ca["wv"], "cross_wo": ca["wo"]}
        dec["norm3"] = jnp.ones((cfg.n_layers, cfg.d_model), dtype)
        params["layers"] = dec
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
    else:  # pragma: no cover
        raise ValueError(f"unknown family {fam}")
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _remat(fn, run: RunConfig):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)  # "full"/"coarse": nothing saveable


def _seq_constrain(x, run: RunConfig):
    """Sequence-parallel residual stream (Megatron-SP via GSPMD)."""
    if run.seq_shard:
        return constrain(x, DATA, MODEL, None)
    return constrain(x, DATA, None, None)


def dense_block(lp, x, cfg, run, positions, causal=True, use_rope=True,
                kv_cache=None, cache_pos=None, enc_out=None):
    """One pre-norm transformer block (+ optional cross-attention)."""
    h, kv = attention_block(lp["attn"], rms_norm(x, lp["norm1"], cfg.norm_eps),
                            cfg, run, positions, kv_cache=kv_cache,
                            cache_pos=cache_pos, causal=causal, use_rope=use_rope)
    x = _seq_constrain(x + h, run)
    if enc_out is not None:
        cross = lp["cross"]
        cp = {"wq": cross["cross_wq"], "wk": cross["cross_wk"],
              "wv": cross["cross_wv"], "wo": cross["cross_wo"]}
        h, _ = attention_block(cp, rms_norm(x, lp["norm3"], cfg.norm_eps),
                               cfg, run, positions, kv_x=enc_out,
                               causal=False, use_rope=False)
        x = _seq_constrain(x + h, run)
    h = mlp_block(lp["mlp"], rms_norm(x, lp["norm2"], cfg.norm_eps), cfg.act)
    return _seq_constrain(x + h, run), kv


def moe_layer_block(lp, x, cfg, run, positions, kv_cache=None, cache_pos=None):
    h, kv = attention_block(lp["attn"], rms_norm(x, lp["norm1"], cfg.norm_eps),
                            cfg, run, positions, kv_cache=kv_cache,
                            cache_pos=cache_pos)
    x = _seq_constrain(x + h, run)
    h, aux = moe_block(lp["moe"], rms_norm(x, lp["norm2"], cfg.norm_eps), cfg,
                       dispatch_mode=run.moe_dispatch)
    return _seq_constrain(x + h, run), kv, aux


def hybrid_shared_block(params, x, x0, inv_proj, cfg, run, positions,
                        kv_cache=None, cache_pos=None, cache_fill=None):
    """Zamba2 shared attention block on concat(x, embed0)."""
    xin = jnp.concatenate([x, x0], axis=-1)
    h, kv = attention_block(params["shared_attn"],
                            rms_norm(xin, params["shared_norm1"], cfg.norm_eps),
                            cfg, run, positions, kv_cache=kv_cache,
                            cache_pos=cache_pos, cache_fill=cache_fill)
    m = mlp_block(params["shared_mlp"],
                  rms_norm(xin, params["shared_norm2"], cfg.norm_eps), cfg.act)
    return _seq_constrain(x + (h + m) @ inv_proj, run), kv


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(params, cfg, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x, DATA, None, None)


def lm_logits(params, cfg, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
    logits = constrain(logits, DATA, None, MODEL)
    if cfg.padded_vocab != cfg.vocab:  # mask vocabulary padding
        cols = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(cols[None, None, :] < cfg.vocab, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# Forward (train / prefill): returns hidden states (+ caches when requested)
# ---------------------------------------------------------------------------


def _stack_scan(body, x, stacked, run: RunConfig, collect=False):
    wrapped = _remat(body, run)

    def f(carry, lp):
        new, out = wrapped(carry, lp)
        return new, (out if collect else None)

    x, ys = jax.lax.scan(f, x, stacked)
    return x, ys


def forward_hidden(
    params: Params, cfg: ModelConfig, run: RunConfig,
    tokens: jnp.ndarray,
    frontend: Optional[jnp.ndarray] = None,
    collect_kv: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Token (+frontend) embeddings through the stack.

    Returns (hidden (B,S,d), extras{aux_loss, kv/ssm caches, enc_out}).
    """
    extras: Dict[str, Any] = {"aux": jnp.zeros((), jnp.float32)}
    fam = cfg.family

    x = embed_tokens(params, cfg, tokens)
    if fam == "vlm" and frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    x = _seq_constrain(x, run)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if fam in ("dense", "vlm"):
        def body(carry, lp):
            new, kv = dense_block(lp, carry, cfg, run, positions)
            return new, (kv if collect_kv else 0)
        x, kvs = _stack_scan(body, x, params["layers"], run, collect=collect_kv)
        if collect_kv:
            extras["kv"] = kvs

    elif fam == "moe":
        if cfg.moe_first_dense:
            def dbody(carry, lp):
                new, kv = dense_block(lp, carry, cfg, run, positions)
                return new, (kv if collect_kv else 0)
            x, dkvs = _stack_scan(dbody, x, params["dense_layers"], run,
                                  collect=collect_kv)
            if collect_kv:
                extras["dense_kv"] = dkvs

        def body(carry, lp):
            new, kv, aux = moe_layer_block(lp, carry, cfg, run, positions)
            return new, ((kv, aux) if collect_kv else aux)
        x, ys = _stack_scan(body, x, params["layers"], run, collect=True)
        if collect_kv:
            extras["kv"], aux = ys
        else:
            aux = ys
        extras["aux"] = jnp.mean(aux)

    elif fam == "ssm":
        def body(carry, lp):
            h = rms_norm(carry, lp["norm1"], cfg.norm_eps)
            y, ssm, conv = mamba_block(lp["mamba"], h, cfg,
                                       chunk_shard=run.ssd_chunk_shard)
            return _seq_constrain(carry + y, run), \
                ((ssm, conv) if collect_kv else 0)
        if run.remat != "none":
            body = jax.checkpoint(body)  # nested: SSD residuals recomputed
        x, states = _stack_scan(body, x, params["layers"], run, collect=collect_kv)
        if collect_kv:
            extras["ssm"] = states

    elif fam == "hybrid":
        x0 = x
        n_groups = cfg.n_layers // cfg.hybrid_attn_every

        def group_body(xg, lp):
            def inner(c, lpi):
                h = rms_norm(c, lpi["norm1"], cfg.norm_eps)
                y, ssm, conv = mamba_block(lpi["mamba"], h, cfg,
                                           chunk_shard=run.ssd_chunk_shard)
                return _seq_constrain(c + y, run), ((ssm, conv) if collect_kv else 0)

            if run.remat != "none":
                inner = jax.checkpoint(inner)  # nested: per-layer SSD remat
            xg, states = jax.lax.scan(
                inner, xg,
                {"mamba": lp["mamba"], "norm1": lp["norm1"]})
            xg, kv = hybrid_shared_block(params, xg, x0, lp["inv_proj"],
                                         cfg, run, positions)
            out = (states, kv) if collect_kv else 0
            return xg, out

        stacked = {"mamba": params["layers"]["mamba"],
                   "norm1": params["layers"]["norm1"],
                   "inv_proj": params["inv_proj"]}
        wrapped = _remat(group_body, run)
        x, ys = jax.lax.scan(wrapped, x, stacked)
        if collect_kv:
            extras["ssm"], extras["kv"] = ys

    elif fam == "audio":
        # Encoder over stub frame embeddings.
        enc = frontend.astype(x.dtype)
        enc = enc + sinusoidal_positions(enc.shape[1], cfg.d_model).astype(enc.dtype)
        enc = _seq_constrain(enc, run)
        epos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None], enc.shape[:2])

        def ebody(carry, lp):
            new, _ = dense_block(lp, carry, cfg, run, epos, causal=False,
                                 use_rope=False)
            return new, None
        enc, _ = _stack_scan(ebody, enc, params["enc_layers"], run)
        enc = rms_norm(enc, params["enc_final_norm"], cfg.norm_eps)
        extras["enc_out"] = enc

        x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)

        def dbody(carry, lp):
            new, kv = dense_block(lp, carry, cfg, run, positions,
                                  use_rope=False, enc_out=enc)
            return new, (kv if collect_kv else 0)
        x, kvs = _stack_scan(dbody, x, params["layers"], run, collect=collect_kv)
        if collect_kv:
            extras["kv"] = kvs
    else:  # pragma: no cover
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, extras


def forward_train(params, cfg, run, tokens, frontend=None):
    """Hidden states for training (logits computed by the loss, which may
    chunk over the sequence to avoid materializing (B,S,V))."""
    return forward_hidden(params, cfg, run, tokens, frontend, collect_kv=False)


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Abstract-friendly cache pytree (zeros; dryrun passes ShapeDtypeStructs)."""
    dtype = _dtype(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    fam = cfg.family

    def kv(layer_count, length):
        return (jnp.zeros((layer_count, batch, length, hkv, dh), dtype),
                jnp.zeros((layer_count, batch, length, hkv, dh), dtype))

    if fam in ("dense", "vlm"):
        cache["k"], cache["v"] = kv(cfg.n_layers, max_len)
    elif fam == "moe":
        n_moe = cfg.n_layers - cfg.moe_first_dense
        cache["k"], cache["v"] = kv(n_moe, max_len)
        if cfg.moe_first_dense:
            cache["dk"], cache["dv"] = kv(cfg.moe_first_dense, max_len)
    elif fam == "ssm":
        di, n, nh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
        conv_ch = di + 2 * n
        cache["ssm"] = jnp.zeros((cfg.n_layers, batch, nh, n, p), dtype)
        cache["conv"] = jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch), dtype)
    elif fam == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        di, n, nh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
        conv_ch = di + 2 * n
        cache["ssm"] = jnp.zeros((n_groups, every, batch, nh, n, p), dtype)
        cache["conv"] = jnp.zeros((n_groups, every, batch, cfg.ssm_conv - 1, conv_ch), dtype)
        wlen = min(cfg.window or max_len, max_len)
        cache["k"], cache["v"] = kv(n_groups, wlen)
    elif fam == "audio":
        cache["k"], cache["v"] = kv(cfg.n_layers, max_len)
        f = cfg.frontend_len
        cache["cross_k"], cache["cross_v"] = kv(cfg.n_layers, f)
    return cache


def prefill(params, cfg, run, tokens, frontend=None):
    """Full-sequence forward that also returns the populated KV caches."""
    hidden, extras = forward_hidden(params, cfg, run, tokens, frontend,
                                    collect_kv=True)
    logits_last = lm_logits(params, cfg, hidden[:, -1:])
    b = tokens.shape[0]
    s = hidden.shape[1]
    cache = init_cache(cfg, b, s)
    if "kv" in extras:
        k, v = extras["kv"]  # (L, B, S, K, D)
        if cfg.family == "hybrid":
            w = cache["k"].shape[2]
            k, v = k[:, :, -w:], v[:, :, -w:]
        cache["k"] = k.astype(cache["k"].dtype)
        cache["v"] = v.astype(cache["v"].dtype)
    if "dense_kv" in extras:
        dk, dv = extras["dense_kv"]
        cache["dk"] = dk.astype(cache["dk"].dtype)
        cache["dv"] = dv.astype(cache["dv"].dtype)
    if "ssm" in extras:
        ssm, conv = extras["ssm"]
        cache["ssm"] = ssm.astype(cache["ssm"].dtype)
        cache["conv"] = conv.astype(cache["conv"].dtype)
    if "enc_out" in extras:  # whisper: precompute cross KV per layer
        enc = extras["enc_out"]
        ca = params["layers"]["cross"]
        b_, f, _ = enc.shape
        ck = jnp.einsum("bfd,ldh->lbfh", enc, ca["cross_wk"])
        cv = jnp.einsum("bfd,ldh->lbfh", enc, ca["cross_wv"])
        hkv, dh = cfg.n_kv_heads, cfg.d_head
        cache["cross_k"] = ck.reshape(cfg.n_layers, b_, f, hkv, dh).astype(
            cache["cross_k"].dtype)
        cache["cross_v"] = cv.reshape(cfg.n_layers, b_, f, hkv, dh).astype(
            cache["cross_v"].dtype)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return logits_last, cache


def decode_step(params, cfg, run, cache, tokens):
    """One decode step: tokens (B,1) + cache -> (logits (B,1,V), new cache).

    The KV/state update chain is the loop-carried dependency the serve loop's
    LCD analysis reports.
    """
    fam = cfg.family
    pos = cache["pos"]
    b = tokens.shape[0]
    x = embed_tokens(params, cfg, tokens)
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    new_cache = dict(cache)

    if fam in ("dense", "vlm", "moe", "audio"):
        if fam == "audio":
            x = x + jax.lax.dynamic_slice_in_dim(
                sinusoidal_positions(cache["k"].shape[2], cfg.d_model),
                pos, 1, axis=0).astype(x.dtype)[None]

        if fam == "moe" and cfg.moe_first_dense:
            def dbody(carry, inputs):
                lp, kl, vl = inputs
                new, (kl2, vl2) = dense_block(lp, carry, cfg, run, positions,
                                              kv_cache=(kl, vl), cache_pos=pos)
                return new, (kl2, vl2)
            x, (dk, dv) = jax.lax.scan(
                dbody, x, (params["dense_layers"], cache["dk"], cache["dv"]))
            new_cache["dk"], new_cache["dv"] = dk, dv

        def body(carry, inputs):
            if fam == "moe":
                lp, kl, vl = inputs
                new, (kl2, vl2), _aux = moe_layer_block(
                    lp, carry, cfg, run, positions, kv_cache=(kl, vl),
                    cache_pos=pos)
                return new, (kl2, vl2)
            if fam == "audio":
                lp, kl, vl, ckl, cvl = inputs
                h, (kl2, vl2) = attention_block(
                    lp["attn"], rms_norm(carry, lp["norm1"], cfg.norm_eps),
                    cfg, run, positions, kv_cache=(kl, vl), cache_pos=pos,
                    use_rope=False)
                xx = carry + h
                cp = {"wq": lp["cross"]["cross_wq"], "wk": lp["cross"]["cross_wk"],
                      "wv": lp["cross"]["cross_wv"], "wo": lp["cross"]["cross_wo"]}
                q = (rms_norm(xx, lp["norm3"], cfg.norm_eps) @ cp["wq"]).reshape(
                    b, 1, cfg.n_heads, cfg.d_head)
                f = ckl.shape[1]
                att = decode_attention(q, ckl, cvl,
                                       jnp.full((b,), f, jnp.int32))
                xx = xx + att.reshape(b, 1, -1) @ cp["wo"]
                h2 = mlp_block(lp["mlp"], rms_norm(xx, lp["norm2"], cfg.norm_eps),
                               cfg.act)
                return xx + h2, (kl2, vl2)
            lp, kl, vl = inputs
            new, (kl2, vl2) = dense_block(lp, carry, cfg, run, positions,
                                          kv_cache=(kl, vl), cache_pos=pos)
            return new, (kl2, vl2)

        if fam == "audio":
            xs = (params["layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"])
        else:
            xs = (params["layers"], cache["k"], cache["v"])
        x, (k, v) = jax.lax.scan(body, x, xs)
        new_cache["k"], new_cache["v"] = k, v

    elif fam == "ssm":
        def body(carry, inputs):
            lp, ssm, conv = inputs
            h = rms_norm(carry, lp["norm1"], cfg.norm_eps)
            y, ssm2, conv2 = mamba_block(lp["mamba"], h, cfg, ssm_state=ssm,
                                         conv_state=conv, single_step=True)
            return carry + y, (ssm2, conv2)
        x, (ssm, conv) = jax.lax.scan(
            body, x, (params["layers"], cache["ssm"], cache["conv"]))
        new_cache["ssm"], new_cache["conv"] = ssm, conv

    elif fam == "hybrid":
        x0 = x
        wlen = cache["k"].shape[2]
        slot = jnp.mod(pos, wlen)

        def group_body(carry, inputs):
            xg = carry
            lp, ssm_g, conv_g, kl, vl = inputs

            def inner(c, xs_inner):
                lpi, ssm, conv = xs_inner
                h = rms_norm(c, lpi["norm1"], cfg.norm_eps)
                y, ssm2, conv2 = mamba_block(lpi["mamba"], h, cfg,
                                             ssm_state=ssm, conv_state=conv,
                                             single_step=True)
                return c + y, (ssm2, conv2)

            xg, (ssm2, conv2) = jax.lax.scan(
                inner, xg,
                ({"mamba": lp["mamba"], "norm1": lp["norm1"]}, ssm_g, conv_g))
            xg, (kl2, vl2) = hybrid_shared_block(
                params, xg, x0, lp["inv_proj"], cfg, run, positions,
                kv_cache=(kl, vl), cache_pos=slot,
                cache_fill=jnp.minimum(pos + 1, wlen))
            return xg, (ssm2, conv2, kl2, vl2)

        stacked = ({"mamba": params["layers"]["mamba"],
                    "norm1": params["layers"]["norm1"],
                    "inv_proj": params["inv_proj"]},
                   cache["ssm"], cache["conv"], cache["k"], cache["v"])
        x, (ssm, conv, k, v) = jax.lax.scan(group_body, x, stacked)
        new_cache.update({"ssm": ssm, "conv": conv, "k": k, "v": v})

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params, cfg, x)
    new_cache["pos"] = pos + 1
    return logits, new_cache
