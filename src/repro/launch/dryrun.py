import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``.lower().compile()`` every (architecture × input
shape × mesh) cell on placeholder devices and record memory / cost /
roofline artifacts (task §MULTI-POD DRY-RUN).

The two env lines above MUST precede every other import — jax locks the
device count on first initialization.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, list_archs  # noqa: E402
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig  # noqa: E402
from repro.core.hlo import roofline_from_compiled  # noqa: E402
from repro.distributed import set_mesh_context  # noqa: E402
from repro.launch.mesh import make_mesh_context  # noqa: E402
from repro.launch.specs import (  # noqa: E402
    batch_shardings, cache_shardings, input_specs, model_flops_estimate,
)
from repro.models import decode_step, prefill  # noqa: E402
from repro.train import make_train_step  # noqa: E402
from repro.train.state import abstract_train_state, state_shardings  # noqa: E402


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """Documented skips (DESIGN.md §5): '' means the cell runs."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention architecture at 500k context: O(S^2) attention "
                "and a 500k dense KV cache are out of scope by design "
                "(sub-quadratic archs run this cell)")
    return ""


def default_run_config(cfg: ModelConfig, shape: ShapeConfig,
                       overrides=None) -> RunConfig:
    kw = dict(
        attention_impl="chunked",
        attention_chunk=512,
        remat="full" if shape.kind == "train" else "none",
        seq_shard=shape.kind == "train",
        zero=shape.kind == "train",
        fsdp=shape.kind == "train",
        loss_chunk=0,
    )
    kw.update(overrides or {})
    return RunConfig(**kw)


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               run_overrides=None):
    """Build the jitted step for one cell and return (lowered, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = cell_skip_reason(cfg, shape)
    if reason:
        return None, {"arch": arch, "shape": shape_name, "skipped": reason}

    ctx = make_mesh_context(multi_pod=multi_pod)
    set_mesh_context(ctx)
    run = default_run_config(cfg, shape, run_overrides)
    specs = input_specs(cfg, shape)
    scalar = NamedSharding(ctx.mesh, P())

    try:
        if shape.kind == "train":
            state = abstract_train_state(cfg)
            st_shard = state_shardings(state, ctx, run)
            bshard = batch_shardings(specs, ctx)
            step = make_train_step(cfg, run)
            jitted = jax.jit(
                step,
                in_shardings=(st_shard, bshard),
                out_shardings=(st_shard, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state, specs)
        elif shape.kind == "prefill":
            state = abstract_train_state(cfg)
            p_shard = state_shardings(state, ctx, run).params
            bshard = batch_shardings(specs, ctx)

            def prefill_step(params, tokens, frontend=None):
                return prefill(params, cfg, run, tokens, frontend=frontend)

            if "frontend" in specs:
                cache_spec = jax.eval_shape(prefill_step, state.params,
                                            specs["tokens"], specs["frontend"])
            else:
                cache_spec = jax.eval_shape(prefill_step, state.params,
                                            specs["tokens"])
            out_cache_shard = cache_shardings(cache_spec[1], ctx)
            jitted = jax.jit(
                prefill_step,
                in_shardings=(p_shard,) + tuple(
                    bshard[k] for k in ("tokens", "frontend") if k in bshard),
                out_shardings=(None, out_cache_shard),
            )
            args = [state.params, specs["tokens"]]
            if "frontend" in specs:
                args.append(specs["frontend"])
            lowered = jitted.lower(*args)
        else:  # decode
            state = abstract_train_state(cfg)
            p_shard = state_shardings(state, ctx, run).params
            c_shard = cache_shardings(specs["cache"], ctx)
            tok_shard = batch_shardings(
                {"tokens": specs["tokens"]}, ctx)["tokens"]

            def serve_step(params, cache, tokens):
                return decode_step(params, cfg, run, cache, tokens)

            jitted = jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard, tok_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(state.params, specs["cache"], specs["tokens"])
        meta = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind,
            "model_flops": model_flops_estimate(cfg, shape),
        }
        return lowered, meta
    finally:
        set_mesh_context(None)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             out_dir=None, run_overrides=None, save_hlo: bool = False,
             name_suffix: str = ""):
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod, run_overrides)
    if lowered is None:
        print(f"SKIP  {arch} x {shape_name}: {meta['skipped']}")
        return meta
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    report = roofline_from_compiled(
        compiled, name=f"{arch}/{shape_name}{name_suffix}",
        model_flops=meta["model_flops"], hlo_text=hlo_text)
    from repro.core.hlo.hotspots import cpu_bf16_artifact_bytes
    artifact = cpu_bf16_artifact_bytes(hlo_text)
    row = report.row()
    row["cpu_convert_artifact_bytes"] = artifact
    row.update(meta)
    row.update({
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "arg_bytes": int(ma.argument_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "out_bytes": int(ma.output_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    })
    mem = row["arg_bytes"] + row["temp_bytes"]
    mem_adj = max(mem - artifact, row["arg_bytes"])
    row["mem_per_device_adjusted"] = mem_adj
    print(f"OK    {arch} x {shape_name} [{row['mesh']}] "
          f"mem/dev={mem / 2**30:.2f}GiB "
          f"(tpu-adj {mem_adj / 2**30:.2f}GiB) "
          f"dominant={row['dominant']} bound={row['bound_s'] * 1e3:.2f}ms "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    print(report.render())

    if out_dir is not None:
        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        stem = f"{arch}__{shape_name}__{row['mesh'].replace('x', '-')}{name_suffix}"
        (out_dir / f"{stem}.json").write_text(json.dumps(row, indent=2, default=str))
        if save_hlo:
            (out_dir / f"{stem}.hlo.txt").write_text(compiled.as_text())
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    failures = []
    for arch, shape, mp in cells:
        try:
            run_cell(arch, shape, mp, out_dir=args.out, save_hlo=args.save_hlo)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape, mp, repr(e)))
            print(f"FAIL  {arch} x {shape} multi_pod={mp}: {e}")
            traceback.print_exc()
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells OK")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
