"""Production mesh construction (task §MULTI-POD DRY-RUN).

``make_production_mesh`` is a function (never module-level state) so
importing this module never touches jax device initialization.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.distributed import MeshContext


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # axis_types/AxisType landed after jax 0.4.37; Auto is the default there
    # and here, so omitting the kwarg is equivalent on every version.
    return jax.make_mesh(shape, axes)


def make_mesh_context(*, multi_pod: bool = False) -> MeshContext:
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    return MeshContext(mesh=mesh, data_axes=data_axes, model_axis="model")


def make_elastic_mesh_context(n_devices: Optional[int] = None,
                              model_parallel: Optional[int] = None) -> MeshContext:
    """Best mesh for an arbitrary device count (elastic re-mesh).

    Picks the largest model-parallel degree that divides the device count
    (capped at 16, the single-pod ICI domain), remaining devices become data
    parallel — the policy ``repro.launch.elastic`` applies after a resize.
    Falls back to an AbstractMesh when planning for a device count the
    current runtime does not have (pure capacity planning).
    """
    n = n_devices or len(jax.devices())
    if model_parallel is None:
        model_parallel = 1
        for cand in (16, 8, 4, 2):
            if n % cand == 0:
                model_parallel = cand
                break
    data = n // model_parallel
    if n <= len(jax.devices()):
        mesh = jax.make_mesh((data, model_parallel), ("data", "model"))
    else:
        # jax 0.4.x AbstractMesh signature: one ((name, size), ...) tuple.
        mesh = jax.sharding.AbstractMesh(
            (("data", data), ("model", model_parallel)))
    return MeshContext(mesh=mesh, data_axes=("data",), model_axis="model")
