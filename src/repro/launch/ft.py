"""Fault tolerance: heartbeats, straggler detection, supervised restarts.

Cluster design (1000+ nodes): every host runs a ``Heartbeat`` reporter; the
supervisor aggregates per-step durations, flags stragglers by robust z-score
(median/MAD), and on failure restarts the step loop from the last complete
checkpoint.  In this container the machinery is exercised with simulated
workers (see tests/test_ft.py) and wired into ``repro.launch.train``.
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class HeartbeatRegistry:
    """Host -> last-seen timestamp; dead = silent for *strictly more than*
    ``timeout_s`` (a beat exactly ``timeout_s`` old is still alive).

    Time is injectable: the registry never reads the wall clock directly —
    it calls ``clock`` (default ``time.monotonic``), so tests drive liveness
    transitions with a fake clock instead of sleeping.  Per-call ``now=``
    overrides remain for callers that already carry timestamps.
    """

    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic
    _beats: Dict[str, float] = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def beat(self, host: str, now: Optional[float] = None) -> None:
        with self._lock:
            self._beats[host] = now if now is not None else self.clock()

    def dead_hosts(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else self.clock()
        with self._lock:
            return [h for h, t in self._beats.items() if now - t > self.timeout_s]

    def alive_count(self, now: Optional[float] = None) -> int:
        return len(self._beats) - len(self.dead_hosts(now))


@dataclass
class StragglerDetector:
    """Flag hosts whose step duration deviates by > ``z_threshold`` robust
    z-scores from the fleet median (median/MAD — stable against the
    stragglers themselves)."""

    z_threshold: float = 4.0
    window: int = 32
    _durations: Dict[str, List[float]] = field(default_factory=dict)

    def record(self, host: str, duration_s: float) -> None:
        hist = self._durations.setdefault(host, [])
        hist.append(duration_s)
        if len(hist) > self.window:
            hist.pop(0)

    def stragglers(self) -> List[str]:
        latest = {h: d[-1] for h, d in self._durations.items() if d}
        if len(latest) < 3:
            return []
        vals = sorted(latest.values())
        median = vals[len(vals) // 2]
        mad = sorted(abs(v - median) for v in vals)[len(vals) // 2]
        scale = max(1.4826 * mad, 1e-3 * max(median, 1e-9), 1e-9)
        return [h for h, v in latest.items()
                if (v - median) / scale > self.z_threshold]


class Supervisor:
    """Run a step function under restart supervision.

    ``step_fn(state, step) -> state`` may raise; the supervisor restores from
    the last checkpoint (via ``restore_fn``) and resumes, up to
    ``max_restarts``.  This is the single-process stand-in for the cluster
    controller restarting failed jobs from the checkpoint store.
    """

    def __init__(self, step_fn: Callable, save_fn: Callable,
                 restore_fn: Callable, *, checkpoint_every: int = 50,
                 max_restarts: int = 3):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, state, start_step: int, num_steps: int):
        step = start_step
        while step < start_step + num_steps:
            try:
                state = self.step_fn(state, step)
                step += 1
                if step % self.checkpoint_every == 0:
                    self.save_fn(step, state)
            except Exception:  # noqa: BLE001
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                state, step = self.restore_fn()
        return state, step
