"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Wires every substrate layer together: config registry, mesh, sharded train
state, deterministic data pipeline, jitted train step, async checkpointing,
heartbeat/straggler monitoring, and checkpoint/restart supervision.  On this
CPU container it trains the tiny variants end-to-end (examples/train_tiny.py);
on a real pod the same driver scales via --no-tiny + the production mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_checkpoint, restore_checkpoint
from repro.configs import RunConfig, get_config, list_archs, tiny_variant
from repro.data import DataPipeline
from repro.distributed import MeshContext, set_mesh_context
from repro.launch.ft import HeartbeatRegistry, StragglerDetector
from repro.launch.mesh import make_elastic_mesh_context, make_mesh_context
from repro.launch.specs import batch_shardings, input_specs
from repro.train import init_train_state, make_train_step
from repro.train.state import abstract_train_state, state_shardings


def train_loop(cfg, run: RunConfig, *, steps: int, global_batch: int,
               seq_len: int, ckpt_dir=None, seed: int = 0,
               mesh_ctx: MeshContext = None, checkpoint_every: int = 0,
               log_every: int = 10, restore: bool = True):
    if mesh_ctx is None:
        mesh_ctx = make_elastic_mesh_context()
    set_mesh_context(mesh_ctx)
    try:
        step_fn = jax.jit(make_train_step(cfg, run), donate_argnums=(0,))
        state = init_train_state(cfg, jax.random.PRNGKey(seed))
        start_step = 0
        ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        if ckpt_dir and restore:
            path = latest_checkpoint(ckpt_dir)
            if path is not None:
                shardings = state_shardings(
                    abstract_train_state(cfg), mesh_ctx, run)
                state, start_step = restore_checkpoint(path, state, shardings)
                print(f"restored checkpoint @ step {start_step}")

        pipeline = DataPipeline(cfg, global_batch, seq_len, seed=seed,
                                start_step=start_step)
        hb = HeartbeatRegistry(timeout_s=120.0)
        stragglers = StragglerDetector()
        host = "host0"

        metrics_out = []
        t_wall = time.time()
        for step in range(start_step, start_step + steps):
            batch = next(pipeline)
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            hb.beat(host)
            stragglers.record(host, dt)
            if (step + 1) % log_every == 0 or step == start_step:
                loss = float(metrics["loss"])
                toks = global_batch * seq_len / dt
                print(f"step {step + 1:5d}  loss {loss:8.4f}  "
                      f"gnorm {float(metrics['grad_norm']):7.3f}  "
                      f"{toks:,.0f} tok/s  {dt * 1e3:.0f} ms/step")
                metrics_out.append({"step": step + 1, "loss": loss,
                                    "tokens_per_s": toks})
            if ckpt and checkpoint_every and (step + 1) % checkpoint_every == 0:
                ckpt.save(step + 1, state)
        if ckpt:
            ckpt.save(start_step + steps, state)
            ckpt.wait()
        pipeline.close()
        wall = time.time() - t_wall
        print(f"done: {steps} steps in {wall:.1f}s "
              f"({steps * global_batch * seq_len / wall:,.0f} tok/s sustained)")
        return state, metrics_out
    finally:
        set_mesh_context(None)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--no-tiny", dest="tiny", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = tiny_variant(cfg)
    run = RunConfig(attention_impl="chunked", attention_chunk=64,
                    remat="full", zero=False, warmup_steps=20,
                    total_steps=args.steps)
    train_loop(cfg, run, steps=args.steps, global_batch=args.global_batch,
               seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
               checkpoint_every=args.checkpoint_every, seed=args.seed)


if __name__ == "__main__":
    main()
