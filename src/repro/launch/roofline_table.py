"""Aggregate dry-run artifacts into the EXPERIMENTS.md roofline tables.

Usage: PYTHONPATH=src python -m repro.launch.roofline_table [--dir artifacts/dryrun]
Emits a markdown table per mesh + a bottleneck summary + hillclimb-candidate
ranking (worst roofline fraction / most collective-bound / paper-representative).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def load_rows(directory):
    rows = []
    for p in sorted(Path(directory).glob("*.json")):
        try:
            rows.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            continue
    return rows


def fmt_table(rows, mesh):
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful | mem/dev (adj) GiB | MFU-at-bound |",
        "|------|-------|-----------|----------|--------------|----------|"
        "--------|-------------------|--------------|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != mesh or "skipped" in r:
            continue
        mem = (r.get("mem_per_device_adjusted")
               or (r["arg_bytes"] + r["temp_bytes"])) / 2**30
        useful = r.get("useful_ratio")
        mfu = r.get("roofline_fraction", 0.0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | "
            f"{useful:.2f} | {mem:.1f} | {mfu * 100:.1f}% |"
            if useful is not None else
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | - | {mem:.1f} | {mfu * 100:.1f}% |"
        )
    return "\n".join(out)


def candidates(rows):
    """Hillclimb picks: worst roofline fraction, most collective-bound,
    paper-representative (largest CP/LCD-style serialization: decode)."""
    single = [r for r in rows if r.get("mesh") == "16x16" and "skipped" not in r]
    if not single:
        return {}
    worst = min(single, key=lambda r: r.get("roofline_fraction", 1.0))
    coll = max(single, key=lambda r: r.get("collective_s", 0.0)
               / max(r.get("bound_s", 1e-9), 1e-9))
    return {"worst_roofline_fraction": f"{worst['arch']} x {worst['shape']} "
                                       f"({worst['roofline_fraction'] * 100:.1f}%)",
            "most_collective_bound": f"{coll['arch']} x {coll['shape']} "
                                     f"(ICI {coll['collective_s']:.3f}s of "
                                     f"bound {coll['bound_s']:.3f}s)"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    rows = load_rows(args.dir)
    for mesh in ("16x16", "2x16x16"):
        n = sum(1 for r in rows if r.get("mesh") == mesh and "skipped" not in r)
        print(f"\n### mesh {mesh} ({n} cells)\n")
        print(fmt_table(rows, mesh))
    print("\n### hillclimb candidates\n")
    for k, v in candidates(rows).items():
        print(f"- {k}: {v}")


if __name__ == "__main__":
    main()
