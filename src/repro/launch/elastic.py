"""Elastic scaling: re-mesh + checkpoint reshard + batch/LR rescale.

When the healthy device count changes (node failure or capacity growth), the
controller: (1) picks a new mesh via ``make_elastic_mesh_context`` (largest
model-parallel degree dividing the new count), (2) restores the latest
checkpoint with the new mesh's shardings (restore is metadata-driven, so any
source mesh works), (3) rescales global batch to keep per-device batch
constant and applies linear LR scaling.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.configs.base import RunConfig
from repro.distributed import MeshContext
from repro.launch.mesh import make_elastic_mesh_context


@dataclass
class ElasticPlan:
    mesh_ctx: MeshContext
    global_batch: int
    learning_rate: float
    reason: str

    @property
    def n_devices(self) -> int:
        return self.mesh_ctx.mesh.size


def plan_resize(
    old_devices: int,
    new_devices: int,
    old_global_batch: int,
    old_lr: float,
    *,
    model_parallel: Optional[int] = None,
) -> ElasticPlan:
    """Compute the post-resize execution plan."""
    ctx = make_elastic_mesh_context(new_devices, model_parallel)
    per_device = max(old_global_batch // max(old_devices, 1), 1)
    data_ways = ctx.data_size
    new_batch = per_device * ctx.mesh.size
    # Keep batch divisible by the data axis.
    new_batch = max((new_batch // data_ways) * data_ways, data_ways)
    new_lr = old_lr * new_batch / max(old_global_batch, 1)
    return ElasticPlan(
        mesh_ctx=ctx,
        global_batch=new_batch,
        learning_rate=new_lr,
        reason=f"resize {old_devices}->{new_devices} devices "
               f"(mesh {dict(ctx.mesh.shape)})",
    )


def apply_resize(plan: ElasticPlan, cfg, run: RunConfig, ckpt_dir):
    """Restore the latest checkpoint onto the new mesh (reshard-on-load)."""
    import jax

    from repro.checkpoint import latest_checkpoint, restore_checkpoint
    from repro.distributed import set_mesh_context
    from repro.train.state import abstract_train_state, state_shardings

    set_mesh_context(plan.mesh_ctx)
    try:
        target = abstract_train_state(cfg)
        shardings = state_shardings(target, plan.mesh_ctx, run)
        path = latest_checkpoint(ckpt_dir)
        if path is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
        state, step = restore_checkpoint(path, target, shardings)
        return state, step
    finally:
        set_mesh_context(None)
