"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Loads (or randomly initializes) a model, then serves a batch of synthetic
requests through the continuous-batching engine — the CPU-scale counterpart
of the decode_* dry-run cells.

``--mode analyze`` serves *kernel-analysis* traffic instead, through the
versioned ``AnalysisService`` request/response API.  ``--arch`` then names a
machine from the architecture registry (``tx2``/``csx``/``zen``/… or any
alias, not an LLM config id), and ``--kernel-file`` analyzes a specific
assembly file instead of the built-in hot-loop pool.  Output is JSON lines —
one ``AnalysisResponse.to_dict()`` per request (malformed requests come back
as per-request error envelopes) plus a final summary object — so other tools
can consume the analyses directly.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.configs import RunConfig, get_config, list_archs, tiny_variant


def _predictors(args) -> tuple:
    if not args.predictors:
        return ()
    return tuple(p.strip() for p in args.predictors.split(",") if p.strip())


def _analysis_pool(args):
    from repro.core.registry import get_arch
    from repro.serving.analysis import AnalysisRequest

    preds = _predictors(args)
    diag = bool(getattr(args, "diagnose", False))
    if args.kernel_file:
        with open(args.kernel_file) as f:
            asm = f.read()
        arch = get_arch(args.arch or "tx2").id
        return [AnalysisRequest(asm=asm, arch=arch, unroll=args.unroll,
                                name=args.kernel_file, predictors=preds,
                                diagnose=diag)]
    if args.arch:
        spec = get_arch(args.arch)
        if spec.sample_asm is None:
            raise SystemExit(f"arch '{spec.id}' has no built-in sample kernel; "
                             f"pass --kernel-file")
        return [
            AnalysisRequest(asm=spec.sample_asm, arch=spec.id, unroll=u,
                            name=f"{spec.id}-gauss-seidel/{u}x",
                            predictors=preds, diagnose=diag)
            for u in (1, args.unroll)
        ]
    # Default synthetic traffic: a stream of requests drawn from a few hot
    # kernels, the common shape of analysis-in-a-tuning-loop workloads.
    tx2, csx = get_arch("tx2"), get_arch("csx")
    return [
        AnalysisRequest(asm=tx2.sample_asm, arch="tx2", unroll=args.unroll,
                        name="gs-tx2", predictors=preds, diagnose=diag),
        AnalysisRequest(asm=csx.sample_asm, arch="csx", unroll=args.unroll,
                        name="gs-csx", predictors=preds, diagnose=diag),
        AnalysisRequest(asm=tx2.sample_asm, arch="tx2", unroll=1,
                        name="gs-tx2-1x", predictors=preds, diagnose=diag),
    ]


def _analysis_service(args):
    """Build the service; resilience turns on when any knob is set."""
    from repro.serving.analysis import AnalysisService
    from repro.serving.faults import FaultInjector
    from repro.serving.resilience import ResilienceConfig

    resilience = None
    if args.deadline_ms > 0 or args.queue_depth > 0 or args.fault_rate > 0:
        resilience = ResilienceConfig(
            request_timeout_s=args.deadline_ms / 1e3,
            max_queue_depth=args.queue_depth,
            min_rung=args.min_rung)
    faults = None
    if args.fault_rate > 0:
        # Spread the configured rate over the expensive stage boundaries.
        faults = FaultInjector(seed=args.fault_seed, rates={
            "stage:dag": args.fault_rate,
            "stage:cp": args.fault_rate,
            "stage:lcd": args.fault_rate,
            "stage:sim": args.fault_rate,
        })
    return AnalysisService(resilience=resilience, faults=faults)


def _serve_analysis(args) -> None:
    try:
        pool = _analysis_pool(args)
    except (ValueError, OSError) as exc:  # unknown arch / bad --kernel-file
        sys.exit(str(exc))
    rng = np.random.default_rng(0)
    requests = [pool[i] for i in rng.integers(0, len(pool), size=args.requests)]

    service = _analysis_service(args)
    t0 = time.time()
    responses = []
    for start in range(0, len(requests), args.batch_size):
        responses.extend(
            service.submit_batch(requests[start:start + args.batch_size]))
    dt = time.time() - t0

    for resp in responses:
        print(json.dumps(resp.to_dict()))
    print(json.dumps({
        "event": "summary",
        "requests": len(responses),
        "errors": sum(1 for r in responses if not r.ok),
        "degraded": sum(1 for r in responses if r.degraded),
        "shed": service.counters["shed"],
        "retries": service.counters["retries"],
        "seconds": dt,
        "req_per_s": len(responses) / max(dt, 1e-9),
        "cache_hits": service.stats["hits"],
        "cache_misses": service.stats["misses"],
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="generate", choices=("generate", "analyze"))
    # Validated per mode: an LLM config id when generating, an architecture-
    # registry id/alias when analyzing (previously both hit list_archs()).
    ap.add_argument("--arch", default=None)
    ap.add_argument("--kernel-file", default=None,
                    help="assembly file to analyze (--mode analyze)")
    ap.add_argument("--unroll", type=int, default=4)
    # Resilience knobs (--mode analyze): any of these switches the service
    # onto the resilient path (deadlines, backpressure, degradation ladder).
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request analysis deadline (0 = none)")
    ap.add_argument("--queue-depth", type=int, default=0,
                    help="admission bound; excess load is shed with "
                         "OVERLOADED + retry_after (0 = unbounded)")
    ap.add_argument("--min-rung", default="parse_only",
                    choices=("full", "bracket", "tp_only", "parse_only"),
                    help="cheapest degradation rung allowed")
    ap.add_argument("--predictors", default="",
                    help="comma-separated predictor subset "
                         "(tp,cp,lcd,sim; empty = all)")
    ap.add_argument("--diagnose", action="store_true",
                    help="attach structured bottleneck findings "
                         "(schema-v4 report 'findings') to each analysis")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="deterministic injected fault rate per stage site")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--no-tiny", dest="tiny", action="store_false")
    args = ap.parse_args()

    if args.mode == "analyze":
        _serve_analysis(args)
        return

    arch = args.arch or "tinyllama-1.1b"
    if arch not in list_archs():
        sys.exit(f"unknown model config '{arch}'; known: "
                 f"{', '.join(list_archs())}")

    import jax

    from repro.models import init_params
    from repro.serving import ServeEngine

    cfg = get_config(arch)
    if args.tiny:
        cfg = tiny_variant(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=args.batch_size)

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, size=args.prompt_len))
               for _ in range(args.requests)]
    frontend = None
    if cfg.frontend != "none":
        frontend = jax.numpy.ones(
            (args.batch_size, cfg.frontend_len, cfg.d_model), jax.numpy.bfloat16)

    t0 = time.time()
    results = engine.generate(prompts, max_new_tokens=args.max_new_tokens,
                              frontend=frontend)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results)
    print(f"{len(results)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    for r in results[:4]:
        print(f"  req {r.request_id}: {r.tokens[:12]}")


if __name__ == "__main__":
    main()
