"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Loads (or randomly initializes) a model, then serves a batch of synthetic
requests through the continuous-batching engine — the CPU-scale counterpart
of the decode_* dry-run cells.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import RunConfig, get_config, list_archs, tiny_variant
from repro.models import init_params
from repro.serving import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--no-tiny", dest="tiny", action="store_false")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = tiny_variant(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=args.batch_size)

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, size=args.prompt_len))
               for _ in range(args.requests)]
    frontend = None
    if cfg.frontend != "none":
        frontend = jax.numpy.ones(
            (args.batch_size, cfg.frontend_len, cfg.d_model), jax.numpy.bfloat16)

    t0 = time.time()
    results = engine.generate(prompts, max_new_tokens=args.max_new_tokens,
                              frontend=frontend)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results)
    print(f"{len(results)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    for r in results[:4]:
        print(f"  req {r.request_id}: {r.tokens[:12]}")


if __name__ == "__main__":
    main()
