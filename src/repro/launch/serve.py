"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Loads (or randomly initializes) a model, then serves a batch of synthetic
requests through the continuous-batching engine — the CPU-scale counterpart
of the decode_* dry-run cells.

``--mode analyze`` serves synthetic *kernel-analysis* traffic instead: many
concurrent requests over a small set of hot assembly loops, amortized through
the batched ``analyze_kernels`` API and its process-level LRU
(``repro.serving.analysis.AnalysisService``).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import RunConfig, get_config, list_archs, tiny_variant


def _serve_analysis(args) -> None:
    from repro.core.validation import GS_CLX_ASM, GS_TX2_ASM
    from repro.serving import AnalysisRequest, AnalysisService

    # Synthetic traffic: a stream of requests drawn from a few hot kernels,
    # the common shape of analysis-in-a-tuning-loop workloads.
    pool = [
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", isa="aarch64", unroll=4),
        AnalysisRequest(asm=GS_CLX_ASM, arch="csx", isa="x86", unroll=4),
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", isa="aarch64", unroll=1),
    ]
    rng = np.random.default_rng(0)
    requests = [pool[i] for i in rng.integers(0, len(pool), size=args.requests)]

    service = AnalysisService()
    t0 = time.time()
    results = []
    for start in range(0, len(requests), args.batch_size):
        results.extend(
            service.analyze_batch(requests[start:start + args.batch_size]))
    dt = time.time() - t0
    print(f"{len(results)} analysis requests in {dt * 1e3:.1f} ms "
          f"({len(results) / max(dt, 1e-9):.0f} req/s)  "
          f"cache hits={service.stats['hits']} misses={service.stats['misses']}")
    for req, analysis in list(zip(requests, results))[:3]:
        bracket = analysis.prediction_bracket()
        print(f"  {req.arch}/{req.unroll}x: "
              f"TP={bracket['lower_bound_tp']:.2f} "
              f"LCD={bracket['expected_lcd']:.2f} "
              f"CP={bracket['upper_bound_cp']:.2f} cy/it")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="generate", choices=("generate", "analyze"))
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--no-tiny", dest="tiny", action="store_false")
    args = ap.parse_args()

    if args.mode == "analyze":
        _serve_analysis(args)
        return

    import jax

    from repro.models import init_params
    from repro.serving import ServeEngine

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = tiny_variant(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=args.batch_size)

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, size=args.prompt_len))
               for _ in range(args.requests)]
    frontend = None
    if cfg.frontend != "none":
        frontend = jax.numpy.ones(
            (args.batch_size, cfg.frontend_len, cfg.d_model), jax.numpy.bfloat16)

    t0 = time.time()
    results = engine.generate(prompts, max_new_tokens=args.max_new_tokens,
                              frontend=frontend)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results)
    print(f"{len(results)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    for r in results[:4]:
        print(f"  req {r.request_id}: {r.tokens[:12]}")


if __name__ == "__main__":
    main()
