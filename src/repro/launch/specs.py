"""ShapeDtypeStruct input specs + sharding specs for every (arch × shape)
cell — the shannon/kernels pattern: weak-type-correct, shardable, zero
allocation."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.distributed import MeshContext
from repro.distributed.sharding import _sanitize
from repro.models import init_cache


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract model inputs for one cell.

    train  : {tokens, labels[, frontend]}
    prefill: {tokens[, frontend]}
    decode : {cache, tokens}
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
        return {"cache": cache, "tokens": sds((b, 1), jnp.int32)}

    specs: Dict[str, Any] = {}
    if cfg.frontend == "vision_stub":
        f = cfg.frontend_len
        specs["tokens"] = sds((b, s - f), jnp.int32)
        specs["frontend"] = sds((b, f, cfg.d_model), dt)
        if shape.kind == "train":
            specs["labels"] = sds((b, s - f), jnp.int32)
    elif cfg.frontend == "audio_stub":
        specs["tokens"] = sds((b, s), jnp.int32)
        specs["frontend"] = sds((b, cfg.frontend_len, cfg.d_model), dt)
        if shape.kind == "train":
            specs["labels"] = sds((b, s), jnp.int32)
    else:
        specs["tokens"] = sds((b, s), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = sds((b, s), jnp.int32)
    return specs


def batch_shardings(specs: Dict[str, Any], ctx: MeshContext) -> Dict[str, Any]:
    data = tuple(ctx.data_axes)
    data = data if len(data) > 1 else data[0]

    def shard(leaf):
        spec = P(data, *([None] * (len(leaf.shape) - 1)))
        return NamedSharding(ctx.mesh, _sanitize(ctx, leaf.shape, spec))

    return {k: jax.tree.map(shard, v) if k != "cache" else v
            for k, v in specs.items()}


def cache_shardings(cache_specs, ctx: MeshContext):
    """KV caches: batch over data, sequence over model (flash-decode split-K
    falls out of GSPMD).  SSM states: batch over data, heads over model."""
    data = tuple(ctx.data_axes)
    data = data if len(data) > 1 else data[0]

    def leaf_spec(path, leaf):
        name = ""
        for p in path:
            if hasattr(p, "key"):
                name = p.key
        nd = len(leaf.shape)
        if name in ("k", "v", "dk", "dv", "cross_k", "cross_v") and nd == 5:
            spec = P(None, data, "model", None, None)
        elif name == "ssm":
            spec = (P(None, data, "model", None, None) if nd == 5
                    else P(None, None, data, "model", None, None))
        elif name == "conv":
            spec = (P(None, data, None, "model") if nd == 4
                    else P(None, None, data, None, "model"))
        else:  # pos and misc scalars
            spec = P()
        return NamedSharding(ctx.mesh, _sanitize(ctx, leaf.shape, spec))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_specs)


def model_flops_estimate(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS per step: 6·N·D train (N = active params), 2·N·D forward."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq
