"""Batched serving engine: continuous-batching prefill + decode loop.

Requests are padded into a fixed decode batch; finished slots are refilled
from the queue (continuous batching).  Greedy sampling by default; the decode
step is the jitted ``repro.models.decode_step`` — the same function the
dry-run lowers for the ``decode_*`` cells.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import decode_step, init_cache, prefill


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    prompt: List[int]
    tokens: List[int]


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, run: Optional[RunConfig] = None,
                 batch_size: int = 4, max_len: int = 512):
        self.cfg = cfg
        self.run = run or RunConfig(attention_impl="chunked", attention_chunk=64,
                                    remat="none", zero=False)
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, cfg, self.run, c, t))
        self._analysis = None

    @property
    def analysis(self):
        """Co-resident kernel-analysis service (lazily constructed), sharing
        this process's analysis LRU — see ``repro.serving.analysis``."""
        if self._analysis is None:
            from repro.serving.analysis import AnalysisService
            self._analysis = AnalysisService()
        return self._analysis

    def analyze_asm(self, requests):
        """Serve a batch of assembly-analysis requests alongside decoding."""
        return self.analysis.analyze_batch(list(requests))

    def generate(self, prompts: List[List[int]], max_new_tokens: int = 16,
                 eos_id: Optional[int] = None,
                 frontend=None) -> List[GenerationResult]:
        """Generate for a list of prompts with continuous batching."""
        results = []
        queue = list(enumerate(prompts))
        while queue:
            wave = queue[:self.batch_size]
            queue = queue[self.batch_size:]
            results.extend(self._run_wave(wave, max_new_tokens, eos_id, frontend))
        return sorted(results, key=lambda r: r.request_id)

    def _run_wave(self, wave, max_new_tokens, eos_id, frontend):
        b = len(wave)
        plen = max(len(p) for _, p in wave)
        tokens = np.zeros((b, plen), np.int32)
        for i, (_, p) in enumerate(wave):
            tokens[i, -len(p):] = p  # left-pad

        logits, cache = prefill(self.params, self.cfg, self.run,
                                jnp.asarray(tokens), frontend=frontend)
        # Grow the cache to the full generation budget.
        cache = self._grow_cache(cache, plen + max_new_tokens, b)

        out_tokens = [[] for _ in range(b)]
        done = [False] * b
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for _ in range(max_new_tokens):
            for i in range(b):
                if not done[i]:
                    tok = int(cur[i])
                    out_tokens[i].append(tok)
                    if eos_id is not None and tok == eos_id:
                        done[i] = True
            if all(done):
                break
            logits, cache = self._decode(self.params, cache, cur[:, None])
            cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

        return [GenerationResult(request_id=rid, prompt=list(p),
                                 tokens=out_tokens[i])
                for i, (rid, p) in enumerate(wave)]

    def _grow_cache(self, cache: Dict, new_len: int, batch: int) -> Dict:
        grown = init_cache(self.cfg, batch, new_len)
        for key in ("k", "v", "dk", "dv"):
            if key in cache and key in grown and \
                    grown[key].shape[2] > cache[key].shape[2]:
                pad = grown[key].shape[2] - cache[key].shape[2]
                grown[key] = jnp.concatenate(
                    [cache[key],
                     jnp.zeros((*cache[key].shape[:2], pad, *cache[key].shape[3:]),
                               cache[key].dtype)], axis=2)
            elif key in cache:
                grown[key] = cache[key]
        for key in ("ssm", "conv", "cross_k", "cross_v"):
            if key in cache:
                grown[key] = cache[key]
        grown["pos"] = cache["pos"]
        return grown
