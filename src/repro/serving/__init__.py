from repro.serving.analysis import (AnalysisRequest, AnalysisService)
from repro.serving.engine import GenerationResult, ServeEngine

__all__ = ["AnalysisRequest", "AnalysisService", "GenerationResult",
           "ServeEngine"]
