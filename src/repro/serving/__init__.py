from repro.serving.analysis import (AnalysisRequest, AnalysisResponse,
                                    AnalysisService)
from repro.serving.faults import FaultInjector, InjectedFault, VirtualClock
from repro.serving.resilience import (AdmissionController, CircuitBreaker,
                                      Deadline, ErrorCode, ResilienceConfig,
                                      RetryPolicy, ServingError, StageTimeout)

__all__ = ["AdmissionController", "AnalysisRequest", "AnalysisResponse",
           "AnalysisService", "CircuitBreaker", "Deadline", "ErrorCode",
           "FaultInjector", "GenerationResult", "InjectedFault",
           "ResilienceConfig", "RetryPolicy", "ServeEngine", "ServingError",
           "StageTimeout", "VirtualClock"]


def __getattr__(attr):
    # The token engine pulls in jax; analysis-only callers (the repro.api
    # facade, serve --mode analyze) should not pay that import.
    if attr in ("GenerationResult", "ServeEngine"):
        from repro.serving import engine
        return getattr(engine, attr)
    raise AttributeError(f"module 'repro.serving' has no attribute '{attr}'")
