from repro.serving.analysis import (AnalysisRequest, AnalysisResponse,
                                    AnalysisService)

__all__ = ["AnalysisRequest", "AnalysisResponse", "AnalysisService",
           "GenerationResult", "ServeEngine"]


def __getattr__(attr):
    # The token engine pulls in jax; analysis-only callers (the repro.api
    # facade, serve --mode analyze) should not pay that import.
    if attr in ("GenerationResult", "ServeEngine"):
        from repro.serving import engine
        return getattr(engine, attr)
    raise AttributeError(f"module 'repro.serving' has no attribute '{attr}'")
