"""Resilience primitives for the serving path.

Production analysis serving cannot assume every request completes: a
pathological kernel can stall the exact port scheduler, a transient fault can
look identical to a permanent one, and an unbounded queue turns one slow wave
into unbounded latency for everyone behind it.  This module provides the
building blocks :class:`repro.serving.analysis.AnalysisService` composes into
a resilient request path:

* a structured **error taxonomy** (:class:`ErrorCode`, :class:`ServingError`)
  replacing free-text error strings, with a transient/permanent split that
  drives retry decisions;
* **deadlines** (:class:`Deadline`) checked cooperatively at analysis stage
  boundaries, plus :func:`run_with_deadline` — a cancellable worker that
  bounds wall-clock time even when a stage blocks between checkpoints;
* **retry with exponential backoff and deterministic jitter**
  (:class:`RetryPolicy`) for faults classified as transient;
* a per-key **circuit breaker** (:class:`CircuitBreaker`):
  CLOSED → OPEN after consecutive failures, OPEN → HALF_OPEN on a timer,
  HALF_OPEN → CLOSED on a successful probe;
* **admission control** (:class:`AdmissionController`): a bounded queue depth
  that sheds excess load with ``OVERLOADED`` + ``retry_after_s`` instead of
  queueing unboundedly.

Every time-dependent component takes an injectable ``clock`` (and ``sleep``),
so the chaos suite (``tests/test_resilience.py``) drives expiry, backoff, and
breaker timers with a virtual clock — deterministically, without sleeping.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

__all__ = [
    "AdmissionController",
    "CircuitBreaker",
    "Deadline",
    "ErrorCode",
    "ResilienceConfig",
    "RetryPolicy",
    "ServingError",
    "StageTimeout",
    "classify_exception",
    "run_with_deadline",
]


class ErrorCode:
    """Structured error codes carried by v2 response envelopes."""

    PARSE_ERROR = "PARSE_ERROR"  # malformed assembly (permanent)
    UNKNOWN_ARCH = "UNKNOWN_ARCH"  # arch/isa not in the registry (permanent)
    STAGE_TIMEOUT = "STAGE_TIMEOUT"  # deadline expired mid-pipeline (transient)
    OVERLOADED = "OVERLOADED"  # shed by admission control / open breaker
    DEGRADED = "DEGRADED"  # answered, but from a cheaper ladder rung
    INTERNAL = "INTERNAL"  # anything else (permanent by default)

    ALL = frozenset({PARSE_ERROR, UNKNOWN_ARCH, STAGE_TIMEOUT, OVERLOADED,
                     DEGRADED, INTERNAL})


class ServingError(Exception):
    """An error with a taxonomy code and a retry classification.

    ``retryable`` means *the same request may succeed if retried* (transient:
    timeouts, shed load); permanent errors (bad asm, unknown arch) never
    succeed on retry and are safe to negatively cache.
    """

    def __init__(self, code: str, message: str, *, retryable: bool = False,
                 retry_after_s: float = 0.0, stage: str = ""):
        super().__init__(message)
        self.code = code
        self.retryable = retryable
        self.retry_after_s = retry_after_s
        self.stage = stage


class StageTimeout(ServingError):
    """A deadline expired before (or during) the named pipeline stage."""

    def __init__(self, stage: str, budget_s: float = 0.0):
        detail = f" (budget {budget_s:.3f}s)" if budget_s else ""
        super().__init__(ErrorCode.STAGE_TIMEOUT,
                         f"deadline expired at stage '{stage}'{detail}",
                         retryable=True, stage=stage)
        self.budget_s = budget_s


def classify_exception(exc: BaseException) -> str:
    """Map an exception to its taxonomy code (free-text errors get a code
    instead of the other way around)."""
    if isinstance(exc, ServingError):
        return exc.code
    if isinstance(exc, ValueError):
        msg = str(exc)
        if msg.startswith("unknown arch") or msg.startswith("unknown isa"):
            return ErrorCode.UNKNOWN_ARCH
        return ErrorCode.PARSE_ERROR
    if isinstance(exc, (SyntaxError, KeyError)):
        return ErrorCode.PARSE_ERROR
    return ErrorCode.INTERNAL


def is_transient(exc: BaseException) -> bool:
    """Whether a retry of the same request could plausibly succeed."""
    return isinstance(exc, ServingError) and exc.retryable


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


@dataclass
class Deadline:
    """An absolute point on an injectable clock.

    ``check(stage)`` is the cooperative cancellation hook threaded through
    the analysis pipeline's stage boundaries: it raises :class:`StageTimeout`
    naming the stage that would have run past the deadline.
    """

    at: float
    clock: Callable[[], float] = time.monotonic
    budget_s: float = 0.0

    @classmethod
    def after(cls, timeout_s: float,
              clock: Callable[[], float] = time.monotonic) -> "Deadline":
        return cls(at=clock() + timeout_s, clock=clock, budget_s=timeout_s)

    def remaining(self) -> float:
        return self.at - self.clock()

    @property
    def expired(self) -> bool:
        return self.clock() >= self.at

    def check(self, stage: str) -> None:
        if self.expired:
            raise StageTimeout(stage, self.budget_s)


def run_with_deadline(fn: Callable[[], object], timeout_s: Optional[float]):
    """Run ``fn`` on a cancellable worker thread, bounded by wall time.

    Cooperative deadline checks only fire *between* stages; a stage that
    blocks internally (or a hostile kernel inside one sweep) would still hang
    the caller.  This wrapper joins the worker for ``timeout_s`` and raises
    :class:`StageTimeout` if it has not finished — the worker itself is
    abandoned (daemonized) and exits at its next cooperative checkpoint.
    """
    if timeout_s is None or timeout_s <= 0:
        return fn()
    box: list = []
    done = threading.Event()

    def target():
        try:
            box.append(("ok", fn()))
        except BaseException as exc:  # noqa: BLE001 — relayed to caller
            box.append(("err", exc))
        finally:
            done.set()

    worker = threading.Thread(target=target, daemon=True,
                              name="analysis-deadline-worker")
    worker.start()
    done.wait(timeout_s)
    if not box:
        raise StageTimeout("worker", timeout_s)
    kind, value = box[0]
    if kind == "err":
        raise value
    return value


# ---------------------------------------------------------------------------
# retry with exponential backoff + jitter
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic, seeded jitter.

    ``backoff(attempt, rng)`` returns the delay before retry ``attempt``
    (0-based): ``base * multiplier**attempt``, clipped to ``max_delay_s``,
    then spread by ±``jitter`` fraction drawn from the caller's ``rng`` —
    a seeded :class:`random.Random`, so a chaos run replays bit-identically.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.01
    multiplier: float = 2.0
    max_delay_s: float = 1.0
    jitter: float = 0.5

    def backoff(self, attempt: int, rng: random.Random) -> float:
        delay = min(self.base_delay_s * self.multiplier ** attempt,
                    self.max_delay_s)
        if self.jitter:
            delay *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return max(delay, 0.0)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


class CircuitBreaker:
    """Consecutive-failure circuit breaker with a half-open probe.

    CLOSED: all requests pass; ``failure_threshold`` consecutive failures
    trip it OPEN.  OPEN: requests are rejected (``allow() == False``) until
    ``reset_timeout_s`` elapses on the injected clock, then the breaker
    half-opens.  HALF_OPEN: one probe request passes; success closes the
    breaker, failure re-opens it (and restarts the timer).
    """

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"

    def __init__(self, failure_threshold: int = 5, reset_timeout_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = failure_threshold
        self.reset_timeout_s = reset_timeout_s
        self.clock = clock
        self._state = self.CLOSED
        self._failures = 0
        self._opened_at = 0.0
        self._probe_inflight = False
        self._lock = threading.Lock()

    @property
    def state(self) -> str:
        with self._lock:
            self._maybe_half_open()
            return self._state

    def _maybe_half_open(self) -> None:
        if (self._state == self.OPEN
                and self.clock() - self._opened_at >= self.reset_timeout_s):
            self._state = self.HALF_OPEN
            self._probe_inflight = False

    def retry_after(self) -> float:
        """Seconds until the breaker half-opens (0 when not OPEN)."""
        with self._lock:
            if self._state != self.OPEN:
                return 0.0
            return max(self.reset_timeout_s - (self.clock() - self._opened_at),
                       0.0)

    def allow(self) -> bool:
        """Admission decision; HALF_OPEN admits exactly one probe."""
        with self._lock:
            self._maybe_half_open()
            if self._state == self.CLOSED:
                return True
            if self._state == self.HALF_OPEN and not self._probe_inflight:
                self._probe_inflight = True
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            self._state = self.CLOSED
            self._failures = 0
            self._probe_inflight = False

    def record_failure(self) -> None:
        with self._lock:
            self._maybe_half_open()
            if self._state == self.HALF_OPEN:
                self._trip()
                return
            self._failures += 1
            if self._state == self.CLOSED and \
                    self._failures >= self.failure_threshold:
                self._trip()

    def _trip(self) -> None:
        self._state = self.OPEN
        self._failures = 0
        self._opened_at = self.clock()
        self._probe_inflight = False


# ---------------------------------------------------------------------------
# admission control / backpressure
# ---------------------------------------------------------------------------


class AdmissionController:
    """Bounded admission: at most ``max_depth`` requests in flight.

    ``try_acquire(n)`` returns how many of ``n`` slots were granted (the
    rest must be shed with ``OVERLOADED`` + ``retry_after_s``); ``release``
    returns slots when their requests finish.  ``max_depth <= 0`` disables
    the bound (admit everything).
    """

    def __init__(self, max_depth: int = 0, retry_after_s: float = 0.05):
        self.max_depth = max_depth
        self.retry_after_s = retry_after_s
        self._depth = 0
        self._shed = 0
        self._lock = threading.Lock()

    @property
    def depth(self) -> int:
        with self._lock:
            return self._depth

    @property
    def shed_total(self) -> int:
        with self._lock:
            return self._shed

    def try_acquire(self, n: int = 1) -> int:
        with self._lock:
            if self.max_depth <= 0:
                self._depth += n
                return n
            granted = max(min(n, self.max_depth - self._depth), 0)
            self._depth += granted
            self._shed += n - granted
            return granted

    def release(self, n: int = 1) -> None:
        with self._lock:
            self._depth = max(self._depth - n, 0)

    def overload_error(self) -> ServingError:
        return ServingError(
            ErrorCode.OVERLOADED,
            f"admission queue full (depth limit {self.max_depth}); "
            f"retry after {self.retry_after_s:.3f}s",
            retryable=True, retry_after_s=self.retry_after_s)


# ---------------------------------------------------------------------------
# service-level configuration
# ---------------------------------------------------------------------------


@dataclass
class ResilienceConfig:
    """Knobs for :class:`repro.serving.analysis.AnalysisService`.

    With the service's default ``resilience=None`` the request path is the
    plain PR-2 pipeline (no deadline checks, no breaker, unbounded
    admission) — zero overhead for callers that don't opt in.
    """

    #: Per-request wall/virtual budget; 0 disables deadlines.
    request_timeout_s: float = 0.0
    #: Optional tighter per-stage budget (<= request budget); 0 disables.
    stage_timeout_s: float = 0.0
    #: Retry transient faults (timeouts, injected transients) this way.
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    #: Bounded admission queue depth; 0 = unbounded (no shedding).
    max_queue_depth: int = 0
    #: Suggested client backoff attached to OVERLOADED responses.
    retry_after_s: float = 0.05
    #: Per-arch breaker: consecutive hard failures before tripping OPEN.
    breaker_failure_threshold: int = 5
    #: Seconds OPEN before the breaker half-opens a probe.
    breaker_reset_s: float = 30.0
    #: Allow falling down the degradation ladder (full → tp_only →
    #: parse_only) instead of erroring when retries are exhausted.
    degrade: bool = True
    #: Cheapest rung degradation may fall to ("full" disables the ladder).
    min_rung: str = "parse_only"
    #: Run each analysis job on a cancellable worker thread so a stage that
    #: blocks *between* checkpoints still respects the wall deadline.  Only
    #: meaningful with the real clock; virtual-clock tests use cooperative
    #: checkpoints alone.
    use_worker: bool = True
    #: Injectable time source shared by deadlines and breakers.
    clock: Callable[[], float] = time.monotonic
    #: Injectable backoff sleep (the chaos suite advances a virtual clock).
    sleep: Callable[[float], None] = time.sleep
    #: Seed for backoff jitter (deterministic retry schedules).
    seed: int = 0

    def jitter_rng(self) -> random.Random:
        return random.Random(self.seed)
