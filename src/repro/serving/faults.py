"""Deterministic fault injection for the serving path.

A resilience layer is only trustworthy if every failure mode it claims to
handle can be *demonstrated* — repeatably, in CI, without flaky sleeps.  This
module provides the harness the chaos suite (``tests/test_resilience.py``)
and the ``resilience`` benchmark drive:

* :class:`FaultInjector` — seeded injection of faults at **named injection
  points** inside :class:`repro.serving.analysis.AnalysisService`:

  ===================  ====================================================
  site                 effect when fired
  ===================  ====================================================
  ``parse``            parser raises (→ ``PARSE_ERROR`` envelope)
  ``stage:resolve``    transient fault before cost resolution
  ``stage:tp``         transient fault before the throughput stage
  ``stage:dag``        transient fault before the DAG build
  ``stage:cp``         transient fault before the critical-path sweep
  ``stage:lcd``        transient fault before the LCD sweep
  ``timeout:<stage>``  virtual clock jumps past the deadline at that stage
  ``cache``            the request's cache entry is evicted before lookup
  ===================  ====================================================

  Firing is deterministic two ways: a per-site Bernoulli ``rate`` drawn from
  a seeded per-site stream (statistical chaos, replayable bit-for-bit), or a
  ``script`` — an explicit set of 1-based call indices (exact choreography
  for unit tests).

* :class:`VirtualClock` — a manually advanced time source satisfying both
  the ``clock`` and ``sleep`` injection points of
  :class:`repro.serving.resilience.ResilienceConfig`, so deadline expiry and
  backoff waits are simulated instead of slept.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Optional

from repro.serving.resilience import ErrorCode, ServingError

__all__ = ["FaultInjector", "FaultSpec", "InjectedFault", "VirtualClock"]


class InjectedFault(ServingError):
    """Raised at an injection point; transient unless configured otherwise."""

    def __init__(self, site: str, call_index: int, *, transient: bool = True):
        code = ErrorCode.STAGE_TIMEOUT if site.startswith("timeout:") \
            else (ErrorCode.PARSE_ERROR if site == "parse"
                  else ErrorCode.INTERNAL)
        super().__init__(code,
                         f"injected fault at '{site}' (call #{call_index})",
                         retryable=transient, stage=site)
        self.site = site
        self.call_index = call_index


class VirtualClock:
    """Deterministic time: advances only when told (or slept on)."""

    def __init__(self, start: float = 0.0):
        self.now = start
        self.sleeps: list = []  # recorded backoff waits, for assertions

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += dt

    def sleep(self, dt: float) -> None:
        self.sleeps.append(dt)
        self.now += dt


@dataclass(frozen=True)
class FaultSpec:
    """How one injection site misbehaves."""

    site: str
    rate: float = 0.0  # Bernoulli firing probability per call
    script: FrozenSet[int] = frozenset()  # exact 1-based call indices
    transient: bool = True  # transient faults are retried; permanent aren't
    advance_s: float = 0.0  # for timeout:* sites — virtual-clock jump


class FaultInjector:
    """Seeded, countable fault injection at named sites.

    Each site keeps its own call counter and its own ``random.Random``
    stream seeded from ``(seed, site)``, so adding a new site (or reordering
    requests across sites) never perturbs another site's firing pattern.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 scripts: Optional[Dict[str, object]] = None,
                 transient: bool = True,
                 clock: Optional[VirtualClock] = None,
                 advance_s: float = 3600.0):
        self.seed = seed
        self.clock = clock
        self.specs: Dict[str, FaultSpec] = {}
        for site, rate in (rates or {}).items():
            self.specs[site] = FaultSpec(site=site, rate=float(rate),
                                         transient=transient,
                                         advance_s=advance_s)
        for site, calls in (scripts or {}).items():
            base = self.specs.get(site)
            self.specs[site] = FaultSpec(
                site=site, rate=base.rate if base else 0.0,
                script=frozenset(int(c) for c in calls),
                transient=transient, advance_s=advance_s)
        self._calls: Dict[str, int] = {}
        self._fired: Dict[str, int] = {}
        self._rngs: Dict[str, random.Random] = {}

    # -- introspection (chaos-suite assertions) ----------------------------

    @property
    def calls(self) -> Dict[str, int]:
        return dict(self._calls)

    @property
    def fired(self) -> Dict[str, int]:
        return dict(self._fired)

    # -- firing ------------------------------------------------------------

    def _rng(self, site: str) -> random.Random:
        rng = self._rngs.get(site)
        if rng is None:
            rng = random.Random(f"{self.seed}:{site}")
            self._rngs[site] = rng
        return rng

    def should_fire(self, site: str) -> bool:
        """Count a call at ``site`` and decide (deterministically) whether
        the configured fault fires.  Sites with no spec never fire but are
        still counted, so tests can assert reach."""
        count = self._calls.get(site, 0) + 1
        self._calls[site] = count
        spec = self.specs.get(site)
        if spec is None:
            return False
        fires = count in spec.script
        if spec.rate > 0.0:
            # Always draw, so firing at call N is independent of scripts.
            fires = self._rng(site).random() < spec.rate or fires
        if fires:
            self._fired[site] = self._fired.get(site, 0) + 1
        return fires

    def check(self, site: str) -> None:
        """Raise :class:`InjectedFault` if the site's fault fires.

        ``timeout:<stage>`` sites never raise directly — they advance the
        virtual clock past any live deadline instead, so the *real* deadline
        machinery (not the injector) produces the ``STAGE_TIMEOUT``.  With no
        virtual clock attached they fall back to raising.
        """
        if not self.should_fire(site):
            return
        if site.startswith("timeout:") and self.clock is not None:
            spec = self.specs[site]
            self.clock.advance(spec.advance_s)
            return
        spec = self.specs[site]
        raise InjectedFault(site, self._calls[site], transient=spec.transient)

    def evicts(self, site: str = "cache") -> bool:
        """Cache-eviction sites report a decision instead of raising."""
        return self.should_fire(site)
