"""Kernel-analysis service for the serving path.

Wraps the batched ``analyze_kernels`` engine behind a request-oriented API:
callers submit raw assembly text (plus ISA / machine / unroll), the service
parses, analyzes, and returns :class:`repro.core.analysis.Analysis` objects.
Amortization happens at three levels:

1. one :class:`MachineModel` instance per architecture lives for the service
   lifetime, so its instruction-lookup memo stays warm across requests;
2. batches go through ``analyze_kernels``, which shares the process-level
   analysis LRU (keyed by kernel text + model name + unroll) — concurrent
   requests for the same hot loop body pay for one analysis;
3. parsed-kernel results are additionally cached here by request key, so a
   repeat request skips even the parse.

This is the CPU-side counterpart of the continuous-batching token engine in
``repro.serving.engine``: many small independent requests, served out of one
warm process.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.analysis import Analysis, analyze_kernels
from repro.core.analysis.analyze import LRUCache
from repro.core.isa import parse_aarch64, parse_x86
from repro.core.machine import (MachineModel, cascade_lake, neoverse_n1,
                                thunderx2, zen, zen2)

_MODEL_FACTORIES: Dict[str, Callable[[], MachineModel]] = {
    "tx2": thunderx2,
    "csx": cascade_lake,
    "zen": zen,
    "zen2": zen2,
    "n1": neoverse_n1,
}

_PARSERS = {
    "aarch64": parse_aarch64,
    "x86": parse_x86,
}


@dataclass(frozen=True)
class AnalysisRequest:
    asm: str
    arch: str = "tx2"  # machine model id (see _MODEL_FACTORIES)
    isa: str = "aarch64"  # "aarch64" | "x86"
    unroll: int = 1
    name: str = "kernel"

    @property
    def key(self) -> Tuple[str, str, str, int]:
        return (self.arch, self.isa, self.asm, self.unroll)


@dataclass
class AnalysisService:
    """Long-lived analysis frontend with per-request LRU caching."""

    max_cached: int = 256
    models: Dict[str, MachineModel] = field(default_factory=dict)
    _cache: LRUCache = None  # type: ignore[assignment]

    def __post_init__(self):
        self._cache = LRUCache(self.max_cached)

    @property
    def stats(self) -> Dict[str, int]:
        return self._cache.stats

    def model_for(self, arch: str) -> MachineModel:
        model = self.models.get(arch)
        if model is None:
            try:
                model = _MODEL_FACTORIES[arch]()
            except KeyError:
                raise ValueError(
                    f"unknown arch '{arch}'; known: {sorted(_MODEL_FACTORIES)}"
                ) from None
            self.models[arch] = model
        return model

    def analyze(self, request: AnalysisRequest) -> Analysis:
        return self.analyze_batch([request])[0]

    def analyze_batch(self, requests: Sequence[AnalysisRequest]) -> List[Analysis]:
        """Serve a wave of analysis requests, deduplicating shared kernels.

        Identical requests within the wave (and across waves, via the LRU)
        are parsed and analyzed once; per (arch, unroll) group the distinct
        kernels go through one ``analyze_kernels`` batch.
        """
        out: List[Optional[Analysis]] = [None] * len(requests)
        # (arch, isa, unroll) -> list of (request positions, parsed kernel)
        groups: Dict[tuple, List[Tuple[List[int], object]]] = {}
        pending: Dict[tuple, List[int]] = {}
        for pos, req in enumerate(requests):
            hit = self._cache.get(req.key)
            if hit is not None:
                out[pos] = hit
                continue
            if req.key in pending:
                # In-wave duplicate: analyzed once, but still a served hit.
                pending[req.key].append(pos)
                self._cache.count_extra_hits()
                continue
            pending[req.key] = [pos]
            parser = _PARSERS.get(req.isa)
            if parser is None:
                raise ValueError(f"unknown isa '{req.isa}'")
            kernel = parser(req.asm, name=req.name)
            groups.setdefault((req.arch, req.unroll), []).append(
                (pending[req.key], kernel))

        for (arch, unroll), entries in groups.items():
            model = self.model_for(arch)
            analyses = analyze_kernels([k for _, k in entries], model,
                                       unroll=unroll)
            for (positions, _), analysis in zip(entries, analyses):
                for pos in positions:
                    out[pos] = analysis
                self._cache.put(requests[positions[0]].key, analysis)
        return out  # type: ignore[return-value]
