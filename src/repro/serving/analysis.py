"""Kernel-analysis service for the serving path.

Request/response frontend over the ``repro.api`` facade: callers submit raw
assembly text plus an architecture id (any registry alias — the arch →
parser/model tables live in :mod:`repro.core.registry`, not here), the
service parses, analyzes, and answers with versioned
:class:`AnalysisResponse` envelopes carrying serializable
:class:`~repro.core.analysis.report.AnalysisReport` payloads.

Failures are structured, not free text (wire contract v2): every error
envelope carries a taxonomy code (``PARSE_ERROR`` / ``UNKNOWN_ARCH`` /
``STAGE_TIMEOUT`` / ``OVERLOADED`` / ``DEGRADED`` / ``INTERNAL``), a
transient/permanent classification, and — for shed load — a ``retry_after_s``
hint.  v1 envelopes (PR 2) still parse; the new fields default.

With a :class:`~repro.serving.resilience.ResilienceConfig` attached, the
request path becomes resilient:

* **admission control** — ``submit_batch`` admits at most
  ``max_queue_depth`` requests; the excess is shed immediately with
  ``OVERLOADED`` + ``retry_after_s`` instead of queueing unboundedly;
* **per-arch circuit breakers** — consecutive backend failures (timeouts,
  internal errors, forced degradations) trip an arch OPEN; its requests are
  rejected until the breaker half-opens on a timer and a probe succeeds;
* **deadlines** — each analysis job runs under a per-request budget,
  checked cooperatively at every pipeline stage boundary and (with the real
  clock) enforced by a cancellable worker thread;
* **retry with exponential backoff + deterministic jitter** for faults
  classified as transient;
* the **degradation ladder** — when retries are exhausted the job falls to
  a cheaper rung (full → bracket → tp_only → parse_only) so one
  pathological kernel yields a partial answer, not a stalled wave.  Degraded responses are
  marked (``degraded``, ``stages_completed``, code ``DEGRADED``) and are
  **never cached as full results**.

Amortization is unchanged from PR 1/2: warm per-arch models, the process
LRU through ``analyze_kernels``, and a request-key cache here.  Fault
injection (:class:`repro.serving.faults.FaultInjector`) hooks named points
(``parse``, ``stage:*``, ``timeout:*``, ``cache``) so the chaos suite can
prove every ladder rung and breaker transition deterministically.
"""

from __future__ import annotations

import copy
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.analysis import (Analysis, AnalysisReport, DEGRADATION_LADDER,
                                 analysis_view, analyze_kernel_rung,
                                 analyze_kernels, normalize_predictors)
from repro.core.analysis.analyze import LRUCache
from repro.core.isa import parse_aarch64, parse_x86
from repro.core.machine import MachineModel
from repro.core.registry import ArchSpec, get_arch
from repro.serving.faults import FaultInjector
from repro.serving.resilience import (AdmissionController, CircuitBreaker,
                                      Deadline, ErrorCode, ResilienceConfig,
                                      ServingError, StageTimeout,
                                      classify_exception, is_transient,
                                      run_with_deadline)

#: Version of the request/response wire contract.  v2 adds structured error
#: codes, retry/backpressure hints, and degradation metadata — additively,
#: so v1 payloads still parse and v1 readers can ignore the new fields.
API_VERSION = 2

_PARSERS = {
    "aarch64": parse_aarch64,
    "x86": parse_x86,
}


@dataclass(frozen=True)
class AnalysisRequest:
    """One kernel-analysis request (v2 wire contract, v1-compatible).

    ``isa`` is optional: when empty it is resolved from the architecture
    registry.  ``arch`` accepts any registry id or alias.  ``timeout_s``
    overrides the service's per-request deadline (0 = use the service
    default; ignored when the service has no resilience config).
    ``predictors`` (additive, v2) selects a subset of
    ``("tp", "cp", "lcd", "sim")``; empty means all.  ``diagnose``
    (additive, v2) attaches the structured bottleneck findings to the
    report (schema v4 ``findings``).
    """

    asm: str
    arch: str = "tx2"
    isa: str = ""  # "aarch64" | "x86" | "" (resolve via registry)
    unroll: int = 1
    name: str = "kernel"
    timeout_s: float = 0.0
    predictors: Tuple[str, ...] = ()
    diagnose: bool = False
    version: int = API_VERSION

    def normalized_predictors(self) -> Tuple[str, ...]:
        """Canonical predictor subset (validated; empty = all)."""
        return normalize_predictors(tuple(self.predictors) or None)

    @property
    def key(self) -> tuple:
        """Canonical cache identity: registry-resolved arch id + isa, so
        aliases (``cascadelake`` vs ``csx``) share one entry, plus the
        normalized predictor subset and the ``diagnose`` flag (a plain
        report must not satisfy a diagnose request).  Falls back to the raw
        fields when the arch (or predictor set) is unknown (the request then
        errors at analysis time anyway).  ``timeout_s`` is deliberately
        excluded: it shapes how long we try, not what the answer is."""
        try:
            preds = self.normalized_predictors()
        except ValueError:
            preds = tuple(self.predictors)
        diag = bool(self.diagnose)
        try:
            spec = get_arch(self.arch)
        except ValueError:
            return (self.arch, self.isa, self.asm, self.unroll, preds, diag)
        return (spec.id, self.isa or spec.isa, self.asm, self.unroll, preds,
                diag)

    def to_dict(self) -> Dict:
        return {"version": self.version, "asm": self.asm, "arch": self.arch,
                "isa": self.isa, "unroll": self.unroll, "name": self.name,
                "timeout_s": self.timeout_s,
                "predictors": list(self.predictors),
                "diagnose": self.diagnose}

    @classmethod
    def from_dict(cls, data: Dict) -> "AnalysisRequest":
        return cls(asm=data["asm"], arch=data.get("arch", "tx2"),
                   isa=data.get("isa", ""), unroll=data.get("unroll", 1),
                   name=data.get("name", "kernel"),
                   timeout_s=data.get("timeout_s", 0.0),
                   predictors=tuple(data.get("predictors", ())),
                   diagnose=data.get("diagnose", False),
                   version=data.get("version", API_VERSION))


@dataclass(frozen=True)
class AnalysisResponse:
    """Versioned per-request envelope: a report, or a structured error.

    ``ok`` keeps its v1 meaning (*there is a report*); a degraded answer is
    ``ok=True`` with ``degraded=True`` and ``error_code="DEGRADED"`` so v1
    readers still consume it while v2 readers can tell it apart.  Hard
    failures carry ``error_code`` plus ``retryable`` (is it worth retrying
    the same request?) and, for shed load, ``retry_after_s``.
    """

    ok: bool
    name: str
    arch: str = ""
    report: Optional[AnalysisReport] = None
    error: str = ""
    error_code: str = ""  # ErrorCode taxonomy; "" on full success
    retryable: bool = False
    retry_after_s: float = 0.0
    degraded: bool = False
    stages_completed: Tuple[str, ...] = ()
    attempts: int = 1
    version: int = API_VERSION

    def to_dict(self) -> Dict:
        return {
            "version": self.version,
            "ok": self.ok,
            "name": self.name,
            "arch": self.arch,
            "error": self.error,
            "error_code": self.error_code,
            "retryable": self.retryable,
            "retry_after_s": self.retry_after_s,
            "degraded": self.degraded,
            "stages_completed": list(self.stages_completed),
            "attempts": self.attempts,
            "report": self.report.to_dict() if self.report is not None else None,
        }

    @classmethod
    def from_dict(cls, data: Dict) -> "AnalysisResponse":
        report = data.get("report")
        return cls(
            ok=data["ok"], name=data.get("name", ""),
            arch=data.get("arch", ""), error=data.get("error", ""),
            # v1 envelopes predate the taxonomy: errors get INTERNAL (the
            # free-text string is preserved verbatim), successes stay clean.
            error_code=data.get("error_code",
                                "" if data["ok"] else ErrorCode.INTERNAL),
            retryable=data.get("retryable", False),
            retry_after_s=data.get("retry_after_s", 0.0),
            degraded=data.get("degraded", False),
            stages_completed=tuple(data.get("stages_completed", ())),
            attempts=data.get("attempts", 1),
            report=AnalysisReport.from_dict(report) if report else None,
            version=data.get("version", API_VERSION),
        )


@dataclass
class _Outcome:
    """Internal per-job result: an analysis (possibly degraded) or an error."""

    analysis: Optional[Analysis] = None
    error: Optional[BaseException] = None
    attempts: int = 1
    retry_after_s: float = 0.0


@dataclass
class AnalysisService:
    """Long-lived analysis frontend with per-request LRU caching.

    ``resilience=None`` (the default) keeps the plain PR-2 request path —
    no deadlines, no admission bound, no breakers, zero added overhead —
    while still answering with structured v2 envelopes.  Attach a
    :class:`ResilienceConfig` (and optionally a :class:`FaultInjector`) to
    turn on the resilient path.
    """

    max_cached: int = 256
    models: Dict[str, MachineModel] = field(default_factory=dict)
    resilience: Optional[ResilienceConfig] = None
    faults: Optional[FaultInjector] = None
    _cache: LRUCache = field(init=False, repr=False)

    def __post_init__(self):
        self._cache = LRUCache(self.max_cached)
        cfg = self.resilience
        self._admission = AdmissionController(
            max_depth=cfg.max_queue_depth if cfg else 0,
            retry_after_s=cfg.retry_after_s if cfg else 0.05)
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._jitter_rng = (cfg or ResilienceConfig()).jitter_rng()
        #: Resilience event counters (separate from cache hit/miss stats).
        self.counters: Dict[str, int] = {
            "shed": 0, "breaker_rejected": 0, "retries": 0,
            "degraded": 0, "timeouts": 0, "faults_injected": 0,
        }

    @property
    def stats(self) -> Dict[str, int]:
        return self._cache.stats

    def model_for(self, arch: str) -> MachineModel:
        """Warm model, resolved through the registry (aliases share one
        instance).  Backed by the facade's process-wide model cache so
        ``repro.api.analyze`` callers and the service share one instruction-
        lookup memo per architecture."""
        spec = get_arch(arch)  # ValueError for unknown archs
        model = self.models.get(spec.id)
        if model is None:
            from repro.api import model_for as shared_model_for
            model = shared_model_for(spec)
            self.models[spec.id] = model
        return model

    def breaker_for(self, arch_id: str) -> CircuitBreaker:
        """The per-arch circuit breaker (created lazily)."""
        breaker = self._breakers.get(arch_id)
        if breaker is None:
            cfg = self.resilience or ResilienceConfig()
            breaker = CircuitBreaker(
                failure_threshold=cfg.breaker_failure_threshold,
                reset_timeout_s=cfg.breaker_reset_s, clock=cfg.clock)
            self._breakers[arch_id] = breaker
        return breaker

    # -- versioned request/response API ------------------------------------

    def submit(self, request: AnalysisRequest) -> AnalysisResponse:
        return self.submit_batch([request])[0]

    def submit_batch(
        self, requests: Sequence[AnalysisRequest]
    ) -> List[AnalysisResponse]:
        """Serve a wave; malformed requests become error responses while the
        rest of the wave is analyzed normally.  With resilience configured,
        load beyond the admission bound is shed up front (``OVERLOADED`` +
        ``retry_after_s``) and each analysis job runs under deadlines,
        retries, breakers, and the degradation ladder."""
        if self.resilience is None and self.faults is None:
            return [self._envelope(req, _Outcome(analysis=res)
                                   if not isinstance(res, BaseException)
                                   else _Outcome(error=res))
                    for req, res in zip(requests, self._analyze_batch(requests))]
        granted = self._admission.try_acquire(len(requests))
        admitted = list(requests)[:granted]
        try:
            outcomes = self._execute_resilient(admitted)
        finally:
            self._admission.release(granted)
        responses = [self._envelope(req, out)
                     for req, out in zip(admitted, outcomes)]
        overload = self._admission.overload_error()
        for req in list(requests)[granted:]:
            self.counters["shed"] += 1
            responses.append(AnalysisResponse(
                ok=False, name=req.name, arch=req.arch,
                error=str(overload), error_code=ErrorCode.OVERLOADED,
                retryable=True, retry_after_s=overload.retry_after_s,
                attempts=0))
        return responses

    def _envelope(self, req: AnalysisRequest,
                  outcome: _Outcome) -> AnalysisResponse:
        if outcome.analysis is not None:
            analysis = outcome.analysis
            report = analysis.to_report()
            degraded = analysis.degraded
            if degraded:
                self.counters["degraded"] += 1
            return AnalysisResponse(
                ok=True, name=req.name, arch=analysis.model.name,
                report=report,
                error_code=ErrorCode.DEGRADED if degraded else "",
                degraded=degraded,
                stages_completed=tuple(analysis.stages_completed),
                attempts=outcome.attempts)
        exc = outcome.error
        assert exc is not None
        code = classify_exception(exc)
        if code == ErrorCode.STAGE_TIMEOUT:
            self.counters["timeouts"] += 1
        return AnalysisResponse(
            ok=False, name=req.name, arch=req.arch,
            error=f"{type(exc).__name__}: {exc}", error_code=code,
            retryable=is_transient(exc),
            retry_after_s=outcome.retry_after_s
            or getattr(exc, "retry_after_s", 0.0),
            attempts=outcome.attempts)

    # -- legacy Analysis API (raises on the first bad request) -------------

    def analyze(self, request: AnalysisRequest) -> Analysis:
        return self.analyze_batch([request])[0]

    def analyze_batch(self, requests: Sequence[AnalysisRequest]) -> List[Analysis]:
        """Serve a wave of analysis requests, deduplicating shared kernels.

        Identical requests within the wave (and across waves, via the LRU)
        are parsed and analyzed once; per (arch, unroll) group the distinct
        kernels share one warm model through ``analyze_kernels``.  Always
        the plain path: no deadlines, no degradation (callers who want the
        resilient behavior use ``submit_batch``).
        """
        results = self._analyze_batch(requests)
        for result in results:
            if isinstance(result, Exception):
                # Raise a copy: raising the (possibly negatively cached,
                # shared) object would attach this frame's traceback to it,
                # pinning the request list for the LRU lifetime.
                raise copy.copy(result)
        return results  # type: ignore[return-value]

    # -- engine ------------------------------------------------------------

    def _resolve(self, req: AnalysisRequest) -> Tuple[ArchSpec, object, tuple]:
        """Registry resolution: (spec, parser, cache key).  The cache key
        uses the canonical arch id, so aliases share entries."""
        spec = get_arch(req.arch)
        if spec.is_hlo:
            raise ValueError(
                f"arch '{spec.id}' is an HLO target; the analysis service "
                f"serves assembly kernels (use repro.api.analyze for HLO)")
        isa = req.isa or spec.isa
        parser = _PARSERS.get(isa)
        if parser is None:
            raise ValueError(f"unknown isa '{isa}'")
        if req.unroll < 1:
            raise ValueError(f"unroll must be >= 1, got {req.unroll}")
        preds = req.normalized_predictors()  # ValueError on unknown names
        # Same shape as AnalysisRequest.key, built from the spec already in
        # hand (the property would resolve the registry a second time).
        return spec, parser, (spec.id, isa, req.asm, req.unroll, preds,
                              bool(req.diagnose))

    def _analyze_batch(
        self, requests: Sequence[AnalysisRequest]
    ) -> List[Union[Analysis, Exception]]:
        out: List[Optional[Union[Analysis, Exception]]] = [None] * len(requests)
        # One job per distinct uncached kernel in the wave.
        jobs: List[Tuple] = []
        pending: Dict[tuple, List[int]] = {}
        for pos, req in enumerate(requests):
            try:
                spec, parser, key = self._resolve(req)
            except ValueError as exc:
                out[pos] = exc
                continue
            hit = self._cache.get(key)
            if hit is not None:
                # Errors are negatively cached: a hot malformed kernel is
                # parsed/analyzed once, not once per retry.
                out[pos] = (hit if isinstance(hit, Exception)
                            else analysis_view(hit, req.name))
                continue
            if key in pending:
                # In-wave duplicate: analyzed once, but still a served hit.
                pending[key].append(pos)
                self._cache.count_extra_hits()
                continue
            try:
                kernel = parser(req.asm, name=req.name)
            except Exception as exc:  # parser rejects malformed asm
                # Strip the traceback before caching: its frames would pin
                # parser locals (including the asm text) for the LRU lifetime.
                out[pos] = exc.with_traceback(None)
                self._cache.put(key, out[pos])
                continue
            pending[key] = [pos]
            # key[-2]/key[-1] are the normalized predictors and the diagnose
            # flag (see _resolve's key shape).
            jobs.append((pending[key], kernel, key, spec.id, req.unroll,
                         key[-2], key[-1]))

        for positions, kernel, key, arch_id, unroll, preds, diag in jobs:
            model = self.model_for(arch_id)  # memoized per service
            try:
                analysis = analyze_kernels([kernel], model, unroll=unroll,
                                           predictors=preds,
                                           diagnose=diag)[0]
            except Exception as exc:
                exc = exc.with_traceback(None)
                for pos in positions:
                    out[pos] = exc
                self._cache.put(key, exc)
                continue
            for pos in positions:
                out[pos] = analysis_view(analysis, requests[pos].name)
            self._cache.put(key, analysis)
        return out  # type: ignore[return-value]

    # -- resilient engine --------------------------------------------------

    def _execute_resilient(
        self, requests: Sequence[AnalysisRequest]
    ) -> List[_Outcome]:
        """The dedup/caching wave loop, with breakers, fault-injection
        points, and per-job deadlines/retries/degradation."""
        cfg = self.resilience or ResilienceConfig()
        out: List[Optional[_Outcome]] = [None] * len(requests)
        jobs: List[Tuple] = []
        pending: Dict[tuple, List[int]] = {}
        for pos, req in enumerate(requests):
            try:
                spec, parser, key = self._resolve(req)
            except ValueError as exc:
                out[pos] = _Outcome(error=exc)
                continue
            breaker = self.breaker_for(spec.id)
            if not breaker.allow():
                self.counters["breaker_rejected"] += 1
                retry_after = breaker.retry_after()
                out[pos] = _Outcome(error=ServingError(
                    ErrorCode.OVERLOADED,
                    f"circuit breaker open for arch '{spec.id}'",
                    retryable=True, retry_after_s=retry_after),
                    retry_after_s=retry_after, attempts=0)
                continue
            if self.faults is not None and self.faults.evicts("cache"):
                self._cache.evict(key)
            hit = self._cache.get(key)
            if hit is not None:
                out[pos] = (_Outcome(error=hit)
                            if isinstance(hit, Exception)
                            else _Outcome(analysis=analysis_view(hit, req.name)))
                continue
            if key in pending:
                pending[key].append(pos)
                self._cache.count_extra_hits()
                continue
            try:
                if self.faults is not None:
                    self.faults.check("parse")
                kernel = parser(req.asm, name=req.name)
            except Exception as exc:
                exc = exc.with_traceback(None)
                out[pos] = _Outcome(error=exc)
                # Negative-cache only permanent parse failures; a transient
                # injected fault must not poison future requests.
                if not is_transient(exc):
                    self._cache.put(key, exc)
                continue
            pending[key] = [pos]
            timeout_s = req.timeout_s or cfg.request_timeout_s
            jobs.append((pending[key], kernel, key, spec.id, req.unroll,
                         timeout_s, key[-2], key[-1]))

        for (positions, kernel, key, arch_id, unroll, timeout_s, preds,
             diag) in jobs:
            model = self.model_for(arch_id)
            outcome = self._run_job(kernel, model, unroll, timeout_s, cfg,
                                    preds, diag)
            breaker = self.breaker_for(arch_id)
            analysis = outcome.analysis
            if analysis is not None and not analysis.degraded:
                # Only full, undegraded successes enter the cache; a
                # degraded answer served from cache would silently demote
                # every future request for that kernel.
                breaker.record_success()
                self._cache.put(key, analysis)
                for pos in positions:
                    out[pos] = _Outcome(
                        analysis=analysis_view(analysis, requests[pos].name),
                        attempts=outcome.attempts)
                continue
            # Degraded answers and backend failures both count against the
            # breaker: either way the backend failed to produce a full
            # report for this arch.
            breaker.record_failure()
            if analysis is not None:
                for pos in positions:
                    out[pos] = _Outcome(
                        analysis=analysis_view(analysis, requests[pos].name),
                        attempts=outcome.attempts)
                continue
            exc = outcome.error
            assert exc is not None
            if isinstance(exc, Exception):
                exc = exc.with_traceback(None)
            if not is_transient(exc):
                self._cache.put(key, exc)
            for pos in positions:
                out[pos] = _Outcome(error=exc, attempts=outcome.attempts,
                                    retry_after_s=outcome.retry_after_s)
        return out  # type: ignore[return-value]

    def _run_job(self, kernel, model, unroll: int, timeout_s: float,
                 cfg: ResilienceConfig,
                 predictors: Optional[tuple] = None,
                 diagnose: bool = False) -> _Outcome:
        """One kernel through deadline + retry + degradation ladder."""
        deadline = (Deadline.after(timeout_s, cfg.clock)
                    if timeout_s > 0 else None)
        if cfg.degrade and cfg.min_rung != "full":
            floor = DEGRADATION_LADDER.index(cfg.min_rung)
            rungs = DEGRADATION_LADDER[:floor + 1]
        else:
            rungs = ("full",)
        attempts = 0
        last_exc: Optional[BaseException] = None
        for rung in rungs:
            checkpoint = (None if rung == "parse_only"
                          else self._make_checkpoint(deadline, cfg))
            max_attempts = max(cfg.retry.max_attempts, 1)
            for attempt in range(max_attempts):
                attempts += 1
                try:
                    analysis = self._run_rung(kernel, model, unroll, rung,
                                              checkpoint, deadline, cfg,
                                              predictors, diagnose)
                    return _Outcome(analysis=analysis, attempts=attempts)
                except Exception as exc:  # noqa: BLE001 — classified below
                    last_exc = exc
                    if not is_transient(exc):
                        break  # permanent: retries can't help, drop a rung
                    expired = deadline is not None and deadline.expired
                    if attempt + 1 < max_attempts and not expired:
                        self.counters["retries"] += 1
                        cfg.sleep(cfg.retry.backoff(attempt, self._jitter_rng))
                        continue
                    break  # retries/deadline exhausted: drop a rung
        assert last_exc is not None
        return _Outcome(error=last_exc, attempts=attempts)

    def _run_rung(self, kernel, model, unroll: int, rung: str, checkpoint,
                  deadline: Optional[Deadline], cfg: ResilienceConfig,
                  predictors: Optional[tuple] = None,
                  diagnose: bool = False):
        def run():
            return analyze_kernel_rung(kernel, model, unroll, rung=rung,
                                       checkpoint=checkpoint,
                                       predictors=predictors,
                                       diagnose=diagnose)

        # The cancellable worker bounds wall time even when a stage blocks
        # between checkpoints; with a virtual clock (chaos tests) wall time
        # never advances on its own, so the cooperative checks suffice.
        if (cfg.use_worker and deadline is not None
                and cfg.clock is time.monotonic and rung != "parse_only"):
            return run_with_deadline(run, deadline.remaining())
        return run()

    def _make_checkpoint(self, deadline: Optional[Deadline],
                         cfg: ResilienceConfig):
        """The cooperative stage-boundary hook: fault injection first (a
        ``timeout:<stage>`` site advances the virtual clock so the *real*
        deadline machinery trips), then the request deadline, then the
        per-stage budget (detected at the next boundary)."""
        state = {"stage": "", "since": cfg.clock()}

        def checkpoint(stage: str) -> None:
            if self.faults is not None:
                try:
                    self.faults.check(f"timeout:{stage}")
                    self.faults.check(f"stage:{stage}")
                except ServingError:
                    self.counters["faults_injected"] += 1
                    raise
            now = cfg.clock()
            prev, prev_since = state["stage"], state["since"]
            state["stage"], state["since"] = stage, now
            if deadline is not None:
                deadline.check(stage)
            if cfg.stage_timeout_s > 0 and prev and \
                    now - prev_since > cfg.stage_timeout_s:
                raise StageTimeout(prev, cfg.stage_timeout_s)

        return checkpoint
