"""Kernel-analysis service for the serving path.

Request/response frontend over the ``repro.api`` facade: callers submit raw
assembly text plus an architecture id (any registry alias — the arch →
parser/model tables live in :mod:`repro.core.registry`, not here), the
service parses, analyzes, and answers with versioned
:class:`AnalysisResponse` envelopes carrying serializable
:class:`~repro.core.analysis.report.AnalysisReport` payloads.  A malformed
request (unknown arch, bad isa, unparsable asm) yields a per-request error
response; the rest of the wave is served normally.

Amortization happens at three levels:

1. one :class:`MachineModel` instance per architecture lives for the service
   lifetime, so its instruction-lookup memo stays warm across requests;
2. batches go through ``analyze_kernels``, which shares the process-level
   analysis LRU (keyed by kernel text + model name + unroll) — concurrent
   requests for the same hot loop body pay for one analysis;
3. parsed-kernel results are additionally cached here by request key, so a
   repeat request skips even the parse.

Cache hits are returned as per-request views carrying the requester's kernel
name (the underlying result objects are shared).  This is the CPU-side
counterpart of the continuous-batching token engine in
``repro.serving.engine``: many small independent requests, served out of one
warm process.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.analysis import (Analysis, AnalysisReport, analysis_view,
                                 analyze_kernels)
from repro.core.analysis.analyze import LRUCache
from repro.core.isa import parse_aarch64, parse_x86
from repro.core.machine import MachineModel
from repro.core.registry import ArchSpec, get_arch

#: Version of the request/response wire contract (bumped on breaking change).
API_VERSION = 1

_PARSERS = {
    "aarch64": parse_aarch64,
    "x86": parse_x86,
}


@dataclass(frozen=True)
class AnalysisRequest:
    """One kernel-analysis request (v1 wire contract).

    ``isa`` is optional: when empty it is resolved from the architecture
    registry.  ``arch`` accepts any registry id or alias.
    """

    asm: str
    arch: str = "tx2"
    isa: str = ""  # "aarch64" | "x86" | "" (resolve via registry)
    unroll: int = 1
    name: str = "kernel"
    version: int = API_VERSION

    @property
    def key(self) -> Tuple[str, str, str, int]:
        """Canonical cache identity: registry-resolved arch id + isa, so
        aliases (``cascadelake`` vs ``csx``) share one entry.  Falls back to
        the raw fields when the arch is unknown (the request then errors at
        analysis time anyway)."""
        try:
            spec = get_arch(self.arch)
        except ValueError:
            return (self.arch, self.isa, self.asm, self.unroll)
        return (spec.id, self.isa or spec.isa, self.asm, self.unroll)

    def to_dict(self) -> Dict:
        return {"version": self.version, "asm": self.asm, "arch": self.arch,
                "isa": self.isa, "unroll": self.unroll, "name": self.name}

    @classmethod
    def from_dict(cls, data: Dict) -> "AnalysisRequest":
        return cls(asm=data["asm"], arch=data.get("arch", "tx2"),
                   isa=data.get("isa", ""), unroll=data.get("unroll", 1),
                   name=data.get("name", "kernel"),
                   version=data.get("version", API_VERSION))


@dataclass(frozen=True)
class AnalysisResponse:
    """Versioned per-request envelope: a report, or an error string."""

    ok: bool
    name: str
    arch: str = ""
    report: Optional[AnalysisReport] = None
    error: str = ""
    version: int = API_VERSION

    def to_dict(self) -> Dict:
        return {
            "version": self.version,
            "ok": self.ok,
            "name": self.name,
            "arch": self.arch,
            "error": self.error,
            "report": self.report.to_dict() if self.report is not None else None,
        }

    @classmethod
    def from_dict(cls, data: Dict) -> "AnalysisResponse":
        report = data.get("report")
        return cls(
            ok=data["ok"], name=data.get("name", ""),
            arch=data.get("arch", ""), error=data.get("error", ""),
            report=AnalysisReport.from_dict(report) if report else None,
            version=data.get("version", API_VERSION),
        )


@dataclass
class AnalysisService:
    """Long-lived analysis frontend with per-request LRU caching."""

    max_cached: int = 256
    models: Dict[str, MachineModel] = field(default_factory=dict)
    _cache: LRUCache = field(init=False, repr=False)

    def __post_init__(self):
        self._cache = LRUCache(self.max_cached)

    @property
    def stats(self) -> Dict[str, int]:
        return self._cache.stats

    def model_for(self, arch: str) -> MachineModel:
        """Warm model, resolved through the registry (aliases share one
        instance).  Backed by the facade's process-wide model cache so
        ``repro.api.analyze`` callers and the service share one instruction-
        lookup memo per architecture."""
        spec = get_arch(arch)  # ValueError for unknown archs
        model = self.models.get(spec.id)
        if model is None:
            from repro.api import model_for as shared_model_for
            model = shared_model_for(spec)
            self.models[spec.id] = model
        return model

    # -- versioned request/response API ------------------------------------

    def submit(self, request: AnalysisRequest) -> AnalysisResponse:
        return self.submit_batch([request])[0]

    def submit_batch(
        self, requests: Sequence[AnalysisRequest]
    ) -> List[AnalysisResponse]:
        """Serve a wave; malformed requests become error responses while the
        rest of the wave is analyzed normally."""
        responses = []
        for req, result in zip(requests, self._analyze_batch(requests)):
            if isinstance(result, Exception):
                responses.append(AnalysisResponse(
                    ok=False, name=req.name, arch=req.arch,
                    error=f"{type(result).__name__}: {result}"))
            else:
                responses.append(AnalysisResponse(
                    ok=True, name=req.name, arch=result.model.name,
                    report=result.to_report()))
        return responses

    # -- legacy Analysis API (raises on the first bad request) -------------

    def analyze(self, request: AnalysisRequest) -> Analysis:
        return self.analyze_batch([request])[0]

    def analyze_batch(self, requests: Sequence[AnalysisRequest]) -> List[Analysis]:
        """Serve a wave of analysis requests, deduplicating shared kernels.

        Identical requests within the wave (and across waves, via the LRU)
        are parsed and analyzed once; per (arch, unroll) group the distinct
        kernels share one warm model through ``analyze_kernels``.
        """
        results = self._analyze_batch(requests)
        for result in results:
            if isinstance(result, Exception):
                # Raise a copy: raising the (possibly negatively cached,
                # shared) object would attach this frame's traceback to it,
                # pinning the request list for the LRU lifetime.
                raise copy.copy(result)
        return results  # type: ignore[return-value]

    # -- engine ------------------------------------------------------------

    def _resolve(self, req: AnalysisRequest) -> Tuple[ArchSpec, object, tuple]:
        """Registry resolution: (spec, parser, cache key).  The cache key
        uses the canonical arch id, so aliases share entries."""
        spec = get_arch(req.arch)
        if spec.is_hlo:
            raise ValueError(
                f"arch '{spec.id}' is an HLO target; the analysis service "
                f"serves assembly kernels (use repro.api.analyze for HLO)")
        isa = req.isa or spec.isa
        parser = _PARSERS.get(isa)
        if parser is None:
            raise ValueError(f"unknown isa '{isa}'")
        if req.unroll < 1:
            raise ValueError(f"unroll must be >= 1, got {req.unroll}")
        # Same shape as AnalysisRequest.key, built from the spec already in
        # hand (the property would resolve the registry a second time).
        return spec, parser, (spec.id, isa, req.asm, req.unroll)

    def _analyze_batch(
        self, requests: Sequence[AnalysisRequest]
    ) -> List[Union[Analysis, Exception]]:
        out: List[Optional[Union[Analysis, Exception]]] = [None] * len(requests)
        # One job per distinct uncached kernel in the wave.
        jobs: List[Tuple[List[int], object, tuple, str, int]] = []
        pending: Dict[tuple, List[int]] = {}
        for pos, req in enumerate(requests):
            try:
                spec, parser, key = self._resolve(req)
            except ValueError as exc:
                out[pos] = exc
                continue
            hit = self._cache.get(key)
            if hit is not None:
                # Errors are negatively cached: a hot malformed kernel is
                # parsed/analyzed once, not once per retry.
                out[pos] = (hit if isinstance(hit, Exception)
                            else analysis_view(hit, req.name))
                continue
            if key in pending:
                # In-wave duplicate: analyzed once, but still a served hit.
                pending[key].append(pos)
                self._cache.count_extra_hits()
                continue
            try:
                kernel = parser(req.asm, name=req.name)
            except Exception as exc:  # parser rejects malformed asm
                # Strip the traceback before caching: its frames would pin
                # parser locals (including the asm text) for the LRU lifetime.
                out[pos] = exc.with_traceback(None)
                self._cache.put(key, out[pos])
                continue
            pending[key] = [pos]
            jobs.append((pending[key], kernel, key, spec.id, req.unroll))

        for positions, kernel, key, arch_id, unroll in jobs:
            model = self.model_for(arch_id)  # memoized per service
            try:
                analysis = analyze_kernels([kernel], model, unroll=unroll)[0]
            except Exception as exc:
                exc = exc.with_traceback(None)
                for pos in positions:
                    out[pos] = exc
                self._cache.put(key, exc)
                continue
            for pos in positions:
                out[pos] = analysis_view(analysis, requests[pos].name)
            self._cache.put(key, analysis)
        return out  # type: ignore[return-value]
