"""Deterministic, restart-safe synthetic token pipeline.

Batches are a pure function of (seed, step, shard) — stateless, so a job
restarted from step N reproduces exactly the stream it would have seen
(checkpoint/restart never replays or skips data).  Host-side generation is
NumPy (cheap, parallel across hosts in a real deployment); arrays are placed
onto the mesh with the batch sharding.  A background prefetch thread keeps
``depth`` batches ahead of the training loop.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import MeshContext


def _batch_rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int, step: int,
               with_frontend: bool = True) -> Dict[str, np.ndarray]:
    """Markov-chain synthetic tokens (non-uniform so loss is learnable)."""
    rng = _batch_rng(seed, step)
    v = cfg.vocab
    # Low-entropy transitions: next = (prev * a + noise) % vocab.
    starts = rng.integers(0, v, size=(batch, 1))
    steps = rng.integers(0, 17, size=(batch, seq))
    tokens = (starts + np.cumsum(steps, axis=1)) % v
    tokens = tokens.astype(np.int32)
    labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    out = {"tokens": tokens, "labels": labels}
    if with_frontend and cfg.frontend != "none":
        f = cfg.frontend_len
        out["frontend"] = rng.standard_normal(
            (batch, f, cfg.d_model)).astype(np.float32) * 0.02
    return out


class _ProducerFailed:
    """Queue sentinel carrying a producer-thread exception to the consumer."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class DataPipeline:
    """Prefetching iterator of device-placed, sharded batches.

    Producer failures propagate: an exception on the prefetch thread is
    delivered to the consumer as a :class:`RuntimeError` (with the original
    as ``__cause__``) at the next ``__next__`` instead of being swallowed
    and leaving the training loop blocked on an empty queue forever.
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 seed: int = 0, start_step: int = 0,
                 mesh_ctx: Optional[MeshContext] = None,
                 shardings: Optional[Dict] = None, depth: int = 2):
        if cfg.frontend == "vision_stub":
            seq = seq - cfg.frontend_len
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed = seed
        self.step = start_step
        self.shardings = shardings
        self.depth = depth
        self._queue: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._failure: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _produce_one(self, step: int):
        host = make_batch(self.cfg, self.batch, self.seq, self.seed, step)
        if self.shardings is not None:
            return {k: jax.device_put(v, self.shardings[k])
                    for k, v in host.items() if k in self.shardings}
        return {k: jax.numpy.asarray(v) for k, v in host.items()}

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            try:
                item = self._produce_one(step)
            except BaseException as exc:  # noqa: BLE001 — relayed to consumer
                self._failure = exc
                self._offer(_ProducerFailed(exc))
                return
            # Produce once, then retry the *same* item until it fits (or we
            # are stopped): regenerating on queue.Full re-ran make_batch and
            # device_put for every retry of the same step.
            if self._offer(item):
                step += 1

    def _offer(self, item) -> bool:
        """Put with stop-polling: returns False only when shutting down."""
        while not self._stop.is_set():
            try:
                self._queue.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self) -> Iterator[Dict]:
        return self

    def __next__(self) -> Dict:
        while True:
            try:
                # Bounded waits so a dead producer surfaces instead of
                # blocking the training loop on an empty queue forever.
                item = self._queue.get(timeout=0.5)
                break
            except queue.Empty:
                if not self._thread.is_alive():
                    exc = self._failure
                    raise RuntimeError(
                        "data pipeline producer thread died"
                        + (f": {type(exc).__name__}: {exc}" if exc else "")
                    ) from exc
        if isinstance(item, _ProducerFailed):
            raise RuntimeError(
                f"data pipeline producer failed: "
                f"{type(item.exc).__name__}: {item.exc}") from item.exc
        self.step += 1
        return item

    def close(self, timeout: float = 2.0):
        """Stop the producer; raises if the thread is stuck (leaking it
        silently would hide a wedged device_put for the process lifetime)."""
        self._stop.set()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            raise RuntimeError(
                "data pipeline producer thread failed to stop within "
                f"{timeout:.1f}s (blocked outside the queue?)")
