"""Fault-tolerant checkpointing.

Design (DESIGN.md §4.2):
  * atomic: write to ``<dir>/tmp.<step>``, fsync, rename to ``step_<N>`` —
    a crash mid-save never corrupts the latest checkpoint;
  * manifest-carrying: ``manifest.json`` records every leaf path, shape,
    dtype, and the logical sharding spec, so restore is mesh-independent
    (an N-chip checkpoint restores onto an M-chip mesh — elastic resize);
  * async: ``AsyncCheckpointer`` snapshots to host memory synchronously
    (cheap) and writes to disk on a background thread, overlapping I/O with
    the next training steps;
  * self-pruning: keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else
            (str(p.idx) if hasattr(p, "idx") else str(p.name))
            for p in path
        )
        flat[key] = leaf
    return flat


def save_checkpoint(directory, step: int, tree, *, keep: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"tmp.{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)  # .tobytes() below handles contiguity
        fname = key.replace("/", "__") + ".bin"
        # Raw bytes + manifest dtype: round-trips ml_dtypes (bfloat16 etc.)
        # that np.save cannot represent.
        (tmp / fname).write_bytes(arr.tobytes())
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
        }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    final = directory / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)

    ckpts = sorted(directory.glob("step_*"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
    return final


def latest_checkpoint(directory) -> Optional[Path]:
    directory = Path(directory)
    if not directory.exists():
        return None
    ckpts = sorted(p for p in directory.glob("step_*")
                   if (p / "manifest.json").exists())
    return ckpts[-1] if ckpts else None


def restore_checkpoint(path, target_tree, shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of ``target_tree``; reshard on load.

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    device_put directly into their (possibly different-mesh) layout.
    """
    path = Path(path)
    with open(path / "manifest.json") as f:
        manifest = json.load(f)

    flat_target = _flatten(target_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    import jax.numpy as jnp

    loaded = {}
    for key, meta in manifest["leaves"].items():
        if key not in flat_target:
            continue
        dtype = jnp.dtype(meta["dtype"])
        arr = np.frombuffer((path / meta["file"]).read_bytes(),
                            dtype=dtype).reshape(meta["shape"])
        tgt = flat_target[key]
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"ckpt {arr.shape} vs target {tgt.shape}")
        if key in flat_shard and flat_shard[key] is not None:
            loaded[key] = jax.device_put(arr.astype(tgt.dtype), flat_shard[key])
        else:
            loaded[key] = jax.numpy.asarray(arr.astype(tgt.dtype))

    missing = set(flat_target) - set(loaded)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")

    leaves_by_key = loaded
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    ordered = []
    for path_keys, _ in paths:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else
            (str(p.idx) if hasattr(p, "idx") else str(p.name))
            for p in path_keys
        )
        ordered.append(leaves_by_key[key])
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest["step"]


class AsyncCheckpointer:
    """Snapshot synchronously, persist asynchronously."""

    def __init__(self, directory, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def save(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree, keep=self.keep)
            except Exception as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
