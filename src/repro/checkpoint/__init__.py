from repro.checkpoint.ckpt import (
    AsyncCheckpointer,
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["AsyncCheckpointer", "latest_checkpoint", "restore_checkpoint",
           "save_checkpoint"]
