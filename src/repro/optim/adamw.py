"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule,
and optional int8 gradient compression (error-feedback free, stochastic-
rounding free — a bandwidth lever for the DP gradient reduction).

Optimizer moments are stored in float32 regardless of parameter dtype and may
be ZeRO-sharded over the data axes (see ``repro.train.state``).
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    mu: Any  # first moment (f32 pytree)
    nu: Any  # second moment (f32 pytree)
    count: jnp.ndarray  # step counter


def adamw_init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(mu=zeros,
                    nu=jax.tree.map(jnp.zeros_like, zeros),
                    count=jnp.zeros((), jnp.int32))


def cosine_schedule(step, base_lr: float, warmup: int, total: int):
    step_f = step.astype(jnp.float32)
    warm = base_lr * (step_f + 1.0) / max(warmup, 1)
    progress = jnp.clip((step_f - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * progress))
    return jnp.where(step_f < warmup, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization (gradient compression)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def adamw_update(
    params,
    grads,
    opt: OptState,
    lr: jnp.ndarray,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if grad_clip > 0 else jnp.ones(())
    grads = jax.tree.map(lambda g: g * scale, grads)

    count = opt.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), opt.nu, grads)

    def upd(p, m, v):
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(mu=mu, nu=nu, count=count), metrics
