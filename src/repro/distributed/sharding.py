"""Sharding rules: logical→physical mapping with divisibility guards.

Axes convention (DESIGN.md §4.1):
  * ``data axes``  — batch / token parallelism: ``("data",)`` single-pod,
    ``("pod", "data")`` multi-pod (outer DP over pods).
  * ``model axis`` — tensor/expert parallelism: ``"model"``.

``constrain`` applies an activation sharding constraint, silently dropping
mesh axes that do not divide the corresponding dimension (e.g. 4 KV heads on
a 16-way model axis → replicated KV) and becoming a no-op when no mesh
context is installed (CPU smoke tests).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class MeshContext:
    mesh: Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    @property
    def data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])


_ctx = threading.local()


def set_mesh_context(ctx: Optional[MeshContext]) -> None:
    _ctx.value = ctx


def current_mesh() -> Optional[MeshContext]:
    return getattr(_ctx, "value", None)


def _filter_axes(ctx: MeshContext, axis):
    """Keep only axes present in the mesh (('pod','data') on a single-pod
    mesh degrades to ('data',))."""
    names = set(ctx.mesh.axis_names)
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in names)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return axis if axis in names else None


def _axis_size(ctx: MeshContext, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([ctx.mesh.shape[a] for a in axis]))
    return int(ctx.mesh.shape[axis])


def _sanitize(ctx: MeshContext, shape: Sequence[int], spec: P) -> P:
    """Drop mesh-absent axes and spec axes that do not divide their dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    clean = []
    for dim, axis in zip(shape, entries):
        axis = _filter_axes(ctx, axis)
        if axis is None:
            clean.append(None)
            continue
        size = _axis_size(ctx, axis)
        clean.append(axis if size > 0 and dim % size == 0 else None)
    while clean and clean[-1] is None:
        clean.pop()
    return P(*clean)


def constrain(x, *spec_entries) -> jax.Array:
    """``with_sharding_constraint`` with divisibility guard; no-op sans mesh."""
    ctx = current_mesh()
    if ctx is None:
        return x
    spec = _sanitize(ctx, x.shape, P(*spec_entries))
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

# Rules keyed by parameter-leaf name; each value maps tensor rank -> spec
# builder (m = model axis).  Layer-stacked tensors have a leading L dim that
# stays unsharded.
def spec_for_path(path: Tuple[str, ...], shape: Tuple[int, ...],
                  model_axis: str = "model") -> P:
    name = path[-1] if path else ""
    m = model_axis
    ndim = len(shape)

    def last(axis):  # shard the last dim
        return P(*([None] * (ndim - 1) + [axis]))

    def second_last(axis):
        if ndim < 2:
            return P()
        return P(*([None] * (ndim - 2) + [axis, None]))

    if name in ("embed",):
        return P(m, None)  # (V, d) vocab-sharded
    if name in ("lm_head",):
        return last(m)  # (d, V)
    if name in ("wq", "wk", "wv", "wi", "w_gate_up", "in_proj", "cross_wk",
                "cross_wv", "cross_wq"):
        return last(m)
    if name in ("wo", "out_proj", "cross_wo"):
        return second_last(m)
    if name in ("moe_wi",):  # (L, E, d, ffe): expert-parallel
        return P(None, m, None, None) if ndim == 4 else second_last(m)
    if name in ("moe_wo",):
        return P(None, m, None, None) if ndim == 4 else second_last(m)
    if name in ("router",):
        return P()
    if name in ("conv_w", "A_log", "D", "dt_bias"):
        return P()  # small SSM tensors: replicated
    # norms, scales, biases, positional tables: replicated
    return P()


def param_sharding_rules(params, mesh_ctx: MeshContext):
    """Pytree of NamedShardings for a parameter pytree (divisibility-guarded)."""

    def leaf_spec(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        spec = spec_for_path(names, leaf.shape, mesh_ctx.model_axis)
        spec = _sanitize(mesh_ctx, leaf.shape, spec)
        return NamedSharding(mesh_ctx.mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def zero_extend(sharding: NamedSharding, shape: Tuple[int, ...],
                mesh_ctx: MeshContext) -> NamedSharding:
    """ZeRO/FSDP: additionally shard the first free divisible dim over the
    data axes.  No-op if the data axes are already used by the spec (a mesh
    axis may appear at most once in a PartitionSpec)."""
    spec = list(sharding.spec) + [None] * (len(shape) - len(sharding.spec))
    used = set()
    for entry in spec:
        for a in (entry if isinstance(entry, (tuple, list)) else (entry,)):
            if a is not None:
                used.add(a)
    data_axes = tuple(mesh_ctx.data_axes)
    if used & set(data_axes):
        return sharding
    size = mesh_ctx.data_size
    for i, (dim, axis) in enumerate(zip(shape, spec)):
        if axis is None and dim % size == 0 and dim >= size:
            spec[i] = data_axes if len(data_axes) > 1 else data_axes[0]
            return NamedSharding(mesh_ctx.mesh, P(*spec))
    return sharding
