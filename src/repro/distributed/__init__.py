from repro.distributed.sharding import (
    MeshContext,
    constrain,
    current_mesh,
    param_sharding_rules,
    set_mesh_context,
    spec_for_path,
    zero_extend,
)

__all__ = [
    "MeshContext", "constrain", "current_mesh", "param_sharding_rules",
    "set_mesh_context", "spec_for_path", "zero_extend",
]
