from repro.kernels.ops import (
    flash_attention,
    flash_decode,
    fused_rmsnorm,
    ssd_chunk_dual,
)

__all__ = ["flash_attention", "flash_decode", "fused_rmsnorm", "ssd_chunk_dual"]
