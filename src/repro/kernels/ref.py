"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (BH, S, D); k/v: (BH, T, D)."""
    bh, s, d = q.shape
    t = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bst,btd->bsd", probs, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths):
    """q: (BK, G, D); k/v: (BK, T, D); lengths: (BK,)."""
    bk, g, d = q.shape
    t = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bgd,btd->bgt", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = jnp.arange(t)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgt,btd->bgd", probs, v.astype(jnp.float32)).astype(q.dtype)


def ssd_intra_chunk_ref(xdt, cum, bm, cm):
    """xdt (B,NC,H,Q,P), cum (B,NC,H,Q), bm/cm (B,NC,Q,N)."""
    xdt = xdt.astype(jnp.float32)
    cum = cum.astype(jnp.float32)
    bm = bm.astype(jnp.float32)
    cm = cm.astype(jnp.float32)
    q = xdt.shape[3]
    scores = jnp.einsum("bcin,bcjn->bcij", cm, bm)
    tri = jnp.tril(jnp.ones((q, q), jnp.float32)) > 0
    diff = cum[..., :, None] - cum[..., None, :]  # (B,NC,H,Q,Q)
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
    m = scores[:, :, None] * decay
    y = jnp.einsum("bchij,bchjp->bchip", m, xdt)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # (B,NC,H,Q)
    states = jnp.einsum("bcjn,bchj,bchjp->bchnp", bm, decay_to_end, xdt)
    return y, states


def rmsnorm_ref(x, w, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)
