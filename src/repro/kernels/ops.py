"""Jit'd public wrappers for the Pallas kernels.

These map model-layer layouts (B, S, H, D) onto the kernels' flattened
layouts, broadcast GQA KV heads, and select ``interpret=True`` automatically
off-TPU (CPU validation mode — the kernel body runs in Python, proving the
tiling/masking logic against ``ref.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_bkgd
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rmsnorm import rmsnorm_rows
from repro.kernels.ssd_scan import ssd_intra_chunk


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    """q: (B,S,H,D); k/v: (B,T,K,D) GQA -> (B,S,H,D)."""
    if interpret is None:
        interpret = _interpret_default()
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    # Broadcast KV heads to query heads, flatten (B,H) -> BH.
    kq = jnp.repeat(k, g, axis=2)
    vq = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = kq.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    vf = vq.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    of = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                              block_q=min(block_q, s), block_k=min(block_k, t),
                              interpret=interpret)
    return of.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q, k_cache, v_cache, lengths, *, block_k=512, interpret=None):
    """q: (B,1,H,D); k/v cache: (B,T,K,D); lengths (B,) -> (B,1,H,D)."""
    if interpret is None:
        interpret = _interpret_default()
    b, _, h, d = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qf = q[:, 0].reshape(b, kh, g, d).reshape(b * kh, g, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * kh, t, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * kh, t, d)
    lens = jnp.repeat(lengths.astype(jnp.int32), kh)
    of = decode_attention_bkgd(qf, kf, vf, lens,
                               block_k=min(block_k, t), interpret=interpret)
    return of.reshape(b, kh * g, d)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_dual(xdt, cum, bm, cm, *, interpret=None):
    """Kernel-backed intra-chunk SSD (see mamba2.ssd_chunked for the full op)."""
    if interpret is None:
        interpret = _interpret_default()
    return ssd_intra_chunk(xdt, cum, bm, cm, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def fused_rmsnorm(x, w, *, eps=1e-5, interpret=None):
    """x: (..., d) RMSNorm with learned scale."""
    if interpret is None:
        interpret = _interpret_default()
    shape = x.shape
    rows = 1
    for dim in shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, shape[-1])
    block = rows
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if rows % cand == 0:
            block = cand
            break
    y = rmsnorm_rows(x2, w, eps=eps, block_rows=block, interpret=interpret)
    return y.reshape(shape)
