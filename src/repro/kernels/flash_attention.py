"""Flash attention (causal / windowed) as a Pallas TPU kernel.

Canonical TPU tiling: grid = (batch*heads, q_blocks, kv_blocks) with the KV
dimension innermost — TPU grids execute sequentially, so the online-softmax
running state (m, l, acc) lives in VMEM scratch that persists across the KV
steps of one (bh, q) cell.  Fully-masked KV blocks are skipped via
``pl.when`` on the block indices (the triangular-skip the XLA chunked
reference cannot express).

Layout: q (BH, S, D), k/v (BH, T, D) — MXU-aligned tiles (block_q x D) and
(block_k x D) with D padded to 128 by the wrapper (`ops.flash_attention`).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level skip: block fully above the diagonal / outside the window.
    run = jnp.bool_(True)
    if causal:
        run &= kj * block_k <= qi * block_q + (block_q - 1)
    if window > 0:
        run &= (kj + 1) * block_k - 1 > qi * block_q - window

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, window: int = 0,
    block_q: int = 128, block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (BH, S, D); k/v: (BH, T, D) -> (BH, S, D)."""
    bh, s, d = q.shape
    t = k.shape[1]
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    n_q, n_k = s // block_q, t // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, n_kv_blocks=n_k)

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
