"""Flash-decode (split-KV single-query attention) Pallas TPU kernel.

One query position per sequence against a long KV cache: grid =
(batch*kv_heads, kv_blocks), KV innermost; online-softmax state for the G
query heads of the group lives in VMEM scratch.  Length masking via the
per-batch ``lengths`` vector (scalar prefetch).

Layout: q (BK, G, D) — G = query heads per KV head (GQA group), kv (BK, T, D),
lengths (BK,) int32.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int, n_kv_blocks: int):
    b = pl.program_id(0)
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    run = kj * block_k < length

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (G, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1)[:, None])
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_bkgd(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, lengths: jnp.ndarray,
    *, block_k: int = 512, interpret: bool = False,
) -> jnp.ndarray:
    """q: (BK, G, D); k/v: (BK, T, D); lengths: (BK,) -> (BK, G, D)."""
    bk, g, d = q.shape
    t = k.shape[1]
    assert t % block_k == 0, (t, block_k)
    n_k = t // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               n_kv_blocks=n_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bk, n_k),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda b, j, lens: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j, lens: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j, lens: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda b, j, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bk, g, d), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)
