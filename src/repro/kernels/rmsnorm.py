"""Fused RMSNorm(+scale) Pallas TPU kernel: one pass over the lane dim."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, d)
    w = w_ref[...].astype(jnp.float32)  # (d,)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w[None, :]).astype(o_ref.dtype)


def rmsnorm_rows(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-5,
                 block_rows: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (R, d) row-major RMSNorm with learned scale w (d,)."""
    r, d = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0, (r, block_rows)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, w)
