"""Mamba-2 SSD intra-chunk kernel (Pallas TPU).

Computes, for one (batch, chunk, head) grid cell, the chunk-diagonal output
block and the chunk's summary state:

    Y_intra[i] = sum_{j<=i} (C_i . B_j) exp(cum_i - cum_j) * xdt_j
    S_chunk    = sum_j B_j^T (exp(cum_last - cum_j) * xdt_j)

The sequential inter-chunk recurrence (the LCD the paper's analysis flags)
stays outside in jnp — it is O(n_chunks) with tiny state and does not
benefit from a kernel.

Layouts (already split per head by the wrapper):
  xdt (B, NC, H, Q, P)   dt-scaled inputs
  cum (B, NC, H, Q)      inclusive cumulative log-decay
  Bm/Cm (B, NC, Q, N)    shared across heads (single B/C group)
Outputs: y (B, NC, H, Q, P), states (B, NC, H, N, P).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, cum_ref, b_ref, c_ref, y_ref, state_ref):
    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)  # (Q, P)
    cum = cum_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    bm = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)  # (Q, N)
    q = xdt.shape[0]

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    valid = ii >= jj
    # Mask the exponent before exp: the upper triangle overflows to inf for
    # long chunks (same guard as the jnp reference).
    decay = jnp.exp(jnp.where(valid, cum[:, None] - cum[None, :], 0.0))
    m = jnp.where(valid, scores * decay, 0.0)
    y_ref[0, 0, 0] = jax.lax.dot_general(
        m, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1] - cum)  # (Q,)
    state = jax.lax.dot_general(
        bm, xdt * decay_to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (N, P)
    state_ref[0, 0, 0] = state.astype(state_ref.dtype)


def ssd_intra_chunk(
    xdt: jnp.ndarray, cum: jnp.ndarray, bm: jnp.ndarray, cm: jnp.ndarray,
    *, interpret: bool = False,
):
    """xdt (B,NC,H,Q,P), cum (B,NC,H,Q), bm/cm (B,NC,Q,N) ->
    (y (B,NC,H,Q,P) f32, states (B,NC,H,N,P) f32)."""
    b, nc, h, q, p = xdt.shape
    n = bm.shape[-1]

    return pl.pallas_call(
        _ssd_kernel,
        grid=(b, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bi, ci, hi: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1, n, p), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, h, q, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, cum, bm, cm)
