"""Benchmark harness: one function per paper table/figure + framework
benches.  Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  table1_gauss_seidel  — paper Table I: TP/LCD/CP on TX2/CLX/ZEN vs. published
  table2_tx2_detail    — paper Table II: TX2 port pressures
  analyzer_throughput  — analysis cost per instruction form (tool perf)
  analyzer_scaling     — analysis cost growth on 32/128/512-instr kernels
  scheduler_balance    — min-max port-assignment cost on the 512-instr kernel
  analysis_service     — serving-path req/s + cache hit rate on a hot trace
  resilience           — resilient path req/s + p99 with 1% faults vs none;
                         appends to the BENCH_serving.json trajectory
  sim_steadystate      — window-limited OoO simulator: steady-state cy/it on
                         the Gauss-Seidel kernels (all five machines) plus
                         wall-time scaling on 32/128/512-instr synthetics;
                         appends to the BENCH_analysis.json trajectory
  diagnostics          — findings-pass overhead: diagnose=True vs plain
                         analyze_kernel on the 512-instr synthetic kernel;
                         appends to the BENCH_analysis.json trajectory
  ibench_pipeline      — §II-B semi-automatic benchmark pipeline on jnp ops
  hlo_roofline         — HLO parse + three-term roofline on a compiled step
  train_step_tiny      — end-to-end tiny train step wall time
  decode_step_tiny     — end-to-end tiny decode step wall time

Pass benchmark names as argv to run a subset (CI smoke runs
``run.py scheduler_balance analyzer_scaling``).
"""

from __future__ import annotations

import time


def _timeit(fn, repeats=5, warmup=1):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")


def table1_gauss_seidel() -> None:
    from repro.core import (analyze_kernel, cascade_lake, parse_aarch64,
                            parse_x86, thunderx2, zen)
    from repro.core.validation import (GS_CLX_ASM, GS_TX2_ASM, GS_ZEN_ASM,
                                       TABLE1)

    for arch, asm, parse, model in [
        ("tx2", GS_TX2_ASM, parse_aarch64, thunderx2()),
        ("csx", GS_CLX_ASM, parse_x86, cascade_lake()),
        ("zen", GS_ZEN_ASM, parse_x86, zen()),
    ]:
        kernel = parse(asm, name="gauss-seidel")
        us = _timeit(lambda: analyze_kernel(kernel, model, unroll=4))
        a = analyze_kernel(kernel, model, unroll=4)
        row = TABLE1[arch]
        match = (round(a.tp_per_it, 2) == row.tp
                 and round(a.lcd_per_it, 2) == row.lcd
                 and round(a.cp_per_it, 2) == row.cp)
        derived = (f"TP={a.tp_per_it:.2f}/{row.tp};LCD={a.lcd_per_it:.2f}/"
                   f"{row.lcd};CP={a.cp_per_it:.2f}/{row.cp};match={match}")
        _row(f"table1_{arch}", us, derived)


def table2_tx2_detail() -> None:
    from repro.core import analyze_kernel, parse_aarch64, thunderx2
    from repro.core.validation import GS_TX2_ASM

    kernel = parse_aarch64(GS_TX2_ASM)
    a = analyze_kernel(kernel, thunderx2(), unroll=4)
    us = _timeit(lambda: a.report())
    pp = {p: round(v / 4, 2) for p, v in a.tp.port_pressure.items() if v}
    _row("table2_tx2", us, ";".join(f"{p}={v}" for p, v in sorted(pp.items())))


def analyzer_throughput() -> None:
    from repro.core import analyze_kernel, parse_x86, cascade_lake
    from repro.core.validation import GS_CLX_ASM

    body = GS_CLX_ASM.replace("# OSACA-END", "") + "# OSACA-END"
    kernel = parse_x86(body)
    model = cascade_lake()
    us = _timeit(lambda: analyze_kernel(kernel, model, unroll=4))
    _row("analyzer_throughput", us,
         f"{us / len(kernel):.2f}us_per_instruction;n={len(kernel)}")


def _synthetic_kernel(n: int):
    """Mixed FP / load / writeback-store / pointer-bump AArch64 kernel."""
    from repro.core import parse_aarch64

    lines, regs = [], 8
    for i in range(n):
        if i % 7 == 3:
            lines.append(f"ldr d{i % regs}, [x1, {8 * (i % 16)}]")
        elif i % 11 == 5:
            lines.append(f"str d{(i + 1) % regs}, [x2], 8")
        elif i % 5 == 2:
            lines.append(f"add x{3 + i % 4}, x{3 + i % 4}, 8")
        else:
            lines.append(f"fadd d{i % regs}, d{(i + 1) % regs}, d{(i + 2) % regs}")
    return parse_aarch64(
        "# OSACA-BEGIN\n" + "\n".join(lines) + "\n# OSACA-END",
        name=f"synthetic-{n}")


def analyzer_scaling() -> None:
    """Full-analysis cost on growing synthetic kernels.

    ``derived`` reports the growth exponent between successive sizes and a
    ``subquadratic`` verdict: each 4x size step must cost well under the 16x
    of quadratic growth (the batched single-sweep engine's point — the seed's
    per-source LCD loop was quadratic).  The 14x threshold plus warmup keeps
    the verdict stable against small-n timing noise.
    """
    from repro.core import analyze_kernel, thunderx2

    model = thunderx2()
    times = {}
    for n in (32, 128, 512):
        kernel = _synthetic_kernel(n)
        times[n] = _timeit(lambda: analyze_kernel(kernel, model),
                           repeats=5, warmup=2)
        _row(f"analyzer_scaling_{n}", times[n], f"n={n}")
    g1 = times[128] / times[32]
    g2 = times[512] / times[128]
    subquadratic = g1 < 14.0 and g2 < 14.0
    _row("analyzer_scaling", times[512],
         f"growth_32_128={g1:.1f}x;growth_128_512={g2:.1f}x;"
         f"subquadratic={subquadratic}")


def scheduler_balance() -> None:
    """Min-max µ-op→port assignment cost, isolated from the rest of the
    analysis.  ``derived`` reports both throughput bounds and checks the
    ordering invariant (balanced <= optimistic) plus the share of a full
    ``analyze_kernel`` the scheduler accounts for — the regression guard for
    the balanced bound staying off the quadratic cliff."""
    from repro.core import analyze_kernel, thunderx2
    from repro.core.analysis import (balance_from_costs, gather_classes,
                                     throughput_from_costs)

    model = thunderx2()
    kernel = _synthetic_kernel(512)
    costs = model.resolve_kernel(kernel)
    us = _timeit(lambda: balance_from_costs(costs, model.ports),
                 repeats=7, warmup=2)
    full_us = _timeit(lambda: analyze_kernel(kernel, model),
                      repeats=3, warmup=1)
    schedule = balance_from_costs(costs, model.ports)
    tp = throughput_from_costs(costs, model)
    assert schedule.bound <= tp.block_throughput + 1e-12
    _row("scheduler_balance", us,
         f"balanced={schedule.bound:.2f};optimistic={tp.block_throughput:.2f};"
         f"classes={len(gather_classes(costs))};n=512;"
         f"share_of_analyze={us / full_us:.3f}")


def analysis_service() -> None:
    """Serving-path throughput: a synthetic hot-loop trace (many repeated
    requests over a few kernels, the analysis-in-a-tuning-loop shape) pushed
    through ``AnalysisService.submit_batch``.  ``derived`` reports req/s and
    the cache hit rate — the amortization the service exists for."""
    import random

    from repro.core.registry import get_arch
    from repro.serving.analysis import AnalysisRequest, AnalysisService

    tx2, csx, zen = get_arch("tx2"), get_arch("csx"), get_arch("zen")
    pool = [
        AnalysisRequest(asm=tx2.sample_asm, arch="tx2", unroll=4, name="gs-tx2"),
        AnalysisRequest(asm=csx.sample_asm, arch="csx", unroll=4, name="gs-csx"),
        AnalysisRequest(asm=zen.sample_asm, arch="zen", unroll=4, name="gs-zen"),
        AnalysisRequest(asm=tx2.sample_asm, arch="tx2", unroll=1, name="gs-tx2-1x"),
    ]
    rng = random.Random(0)
    trace = [pool[rng.randrange(len(pool))] for _ in range(256)]

    service = AnalysisService()
    t0 = time.perf_counter()
    responses = []
    for start in range(0, len(trace), 16):
        responses.extend(service.submit_batch(trace[start:start + 16]))
    dt = time.perf_counter() - t0

    assert all(r.ok for r in responses)
    hits, misses = service.stats["hits"], service.stats["misses"]
    hit_rate = hits / max(hits + misses, 1)
    _row("analysis_service", dt * 1e6 / len(trace),
         f"req_per_s={len(trace) / dt:.0f};hit_rate={hit_rate:.3f};"
         f"requests={len(trace)};hits={hits};misses={misses}")


def _service_pool():
    from repro.core.registry import get_arch
    from repro.serving.analysis import AnalysisRequest

    tx2, csx, zen = get_arch("tx2"), get_arch("csx"), get_arch("zen")
    return [
        AnalysisRequest(asm=tx2.sample_asm, arch="tx2", unroll=4, name="gs-tx2"),
        AnalysisRequest(asm=csx.sample_asm, arch="csx", unroll=4, name="gs-csx"),
        AnalysisRequest(asm=zen.sample_asm, arch="zen", unroll=4, name="gs-zen"),
        AnalysisRequest(asm=tx2.sample_asm, arch="tx2", unroll=1, name="gs-tx2-1x"),
    ]


def resilience() -> None:
    """Resilient serving path under deterministic chaos.

    Two identical single-request traces (per-request submits, so the latency
    distribution is per request, not per wave) through the *resilient*
    service — once clean, once with a 1% seeded fault rate at the expensive
    stage boundaries.  Caching is disabled so every request exercises the
    full analysis path; faults recover via retry/backoff or the degradation
    ladder, never as failed requests.  Results are appended to the
    ``BENCH_serving.json`` trajectory file so serving-path perf regressions
    are visible per PR.
    """
    import json
    import random
    from pathlib import Path

    from repro.serving.analysis import AnalysisService
    from repro.serving.faults import FaultInjector
    from repro.serving.resilience import ResilienceConfig

    rng = random.Random(0)
    pool = _service_pool()
    trace = [pool[rng.randrange(len(pool))] for _ in range(256)]

    def run(service):
        lats = []
        t0 = time.perf_counter()
        for req in trace:
            s = time.perf_counter()
            resp = service.submit(req)
            assert resp.ok  # faults degrade or retry; they never fail
            lats.append((time.perf_counter() - s) * 1e6)
        dt = time.perf_counter() - t0
        lats.sort()
        pct = lambda q: lats[min(int(len(lats) * q), len(lats) - 1)]  # noqa: E731
        return {"req_per_s": round(len(trace) / dt, 1),
                "p50_us": round(pct(0.50), 1), "p99_us": round(pct(0.99), 1)}

    cfg = lambda: ResilienceConfig(request_timeout_s=0.25)  # noqa: E731
    baseline = AnalysisService(max_cached=0, resilience=cfg())
    clean = run(baseline)
    faulty = AnalysisService(
        max_cached=0, resilience=cfg(),
        faults=FaultInjector(seed=0, rates={"stage:cp": 0.01,
                                            "stage:dag": 0.01}))
    chaotic = run(faulty)
    chaotic.update({k: faulty.counters[k]
                    for k in ("retries", "degraded", "timeouts")})

    _row("resilience_clean", 1e6 / max(clean["req_per_s"], 1e-9),
         f"req_per_s={clean['req_per_s']};p50_us={clean['p50_us']};"
         f"p99_us={clean['p99_us']}")
    _row("resilience_faulty_1pct", 1e6 / max(chaotic["req_per_s"], 1e-9),
         f"req_per_s={chaotic['req_per_s']};p50_us={chaotic['p50_us']};"
         f"p99_us={chaotic['p99_us']};retries={chaotic['retries']};"
         f"degraded={chaotic['degraded']}")

    path = Path(__file__).resolve().parent.parent / "BENCH_serving.json"
    doc = {"benchmark": "serving", "entries": []}
    if path.exists():
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError:
            pass
    doc["entries"].append({
        "bench": "resilience", "requests": len(trace),
        "fault_rate": 0.01, "clean": clean, "faulty_1pct": chaotic,
    })
    path.write_text(json.dumps(doc, indent=2) + "\n")


def _synthetic_kernel_x86(n: int):
    """Mixed FP / load / store / pointer-bump x86 kernel (AT&T syntax),
    the x86 twin of :func:`_synthetic_kernel`."""
    from repro.core import parse_x86

    lines, regs = [], 8
    for i in range(n):
        if i % 7 == 3:
            lines.append(f"movsd {8 * (i % 16)}(%rsi,%rbx,8), %xmm{i % regs}")
        elif i % 11 == 5:
            lines.append(f"movsd %xmm{(i + 1) % regs}, {8 * (i % 16)}(%rax)")
        elif i % 5 == 2:
            lines.append("addq $8, %rdx")
        else:
            lines.append(f"vaddsd %xmm{i % regs}, %xmm{(i + 1) % regs}, "
                         f"%xmm{(i + 2) % regs}")
    return parse_x86(
        "# OSACA-BEGIN\n" + "\n".join(lines) + "\n# OSACA-END",
        name=f"synthetic-x86-{n}")


def sim_steadystate() -> None:
    """Window-limited OoO simulator cost and predictions.

    Per machine: the Gauss-Seidel sample kernel's steady-state point
    prediction (cy/it at 4x unroll, with the bracket it must sit inside and
    the copies-to-convergence count), then simulator wall time on growing
    synthetic kernels.  The run is appended to ``BENCH_analysis.json`` so the
    simulator's speed *and* its predictions are tracked per PR — a silent
    prediction shift is as much a regression as a slowdown.
    """
    import json
    from pathlib import Path

    from repro.core import analyze_kernel, thunderx2, cascade_lake, zen
    from repro.core.machine import neoverse_n1, zen2
    from repro.core.registry import get_arch
    from repro.core.sim import simulate_kernel

    entry = {"bench": "sim_steadystate", "gauss_seidel": {}, "scaling": {}}
    for arch, mk in [("tx2", thunderx2), ("csx", cascade_lake), ("zen", zen),
                     ("zen2", zen2), ("n1", neoverse_n1)]:
        spec = get_arch(arch)
        kernel = spec.parser(spec.sample_asm, name="gauss-seidel")
        model = mk()
        us = _timeit(lambda: simulate_kernel(kernel, model), repeats=5,
                     warmup=1)
        a = analyze_kernel(kernel, model, unroll=4)
        sim = a.sim
        inside = (a.tp.balanced_throughput - 1e-9 <= sim.cy_per_block
                  <= max(a.cp.length, a.tp.balanced_throughput) + 1e-9)
        assert inside, f"{arch}: sim escaped the [TP, CP] bracket"
        derived = (f"sim={a.sim_per_it:.2f}cy/it;"
                   f"tp={a.tp_balanced_per_it:.2f};cp={a.cp_per_it:.2f};"
                   f"copies={sim.copies};converged={sim.converged};"
                   f"limiter={sim.limiter}")
        _row(f"sim_steadystate_{arch}", us, derived)
        entry["gauss_seidel"][arch] = {
            "sim_cy_per_it": round(a.sim_per_it, 4),
            "tp_cy_per_it": round(a.tp_balanced_per_it, 4),
            "cp_cy_per_it": round(a.cp_per_it, 4),
            "copies": sim.copies, "converged": sim.converged,
            "limiter": sim.limiter, "us_per_sim": round(us, 1),
        }

    scaling_models = [("tx2", thunderx2(), _synthetic_kernel),
                      ("csx", cascade_lake(), _synthetic_kernel_x86)]
    for arch, model, make in scaling_models:
        per_arch = {}
        for n in (32, 128, 512):
            kernel = make(n)
            us = _timeit(lambda: simulate_kernel(kernel, model), repeats=3,
                         warmup=1)
            result = simulate_kernel(kernel, model)
            _row(f"sim_steadystate_scale_{arch}_{n}", us,
                 f"n={n};cy_block={result.cy_per_block:.1f};"
                 f"copies={result.copies}")
            per_arch[str(n)] = {"us_per_sim": round(us, 1),
                                "cy_per_block": round(result.cy_per_block, 2),
                                "copies": result.copies}
        entry["scaling"][arch] = per_arch

    path = Path(__file__).resolve().parent.parent / "BENCH_analysis.json"
    doc = {"benchmark": "analysis", "entries": []}
    if path.exists():
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError:
            pass
    doc["entries"].append(entry)
    path.write_text(json.dumps(doc, indent=2) + "\n")


def diagnostics() -> None:
    """Findings-pass overhead on the 512-instr synthetic kernel.

    ``derived`` reports the diagnose=True / diagnose=False wall-time ratio
    plus the finding count — the regression guard for the plain path staying
    free (the pass must cost ~nothing when not requested, and single-digit
    percent when it is).  Appended to the ``BENCH_analysis.json`` trajectory.
    """
    import json
    from pathlib import Path

    from repro.core import analyze_kernel, thunderx2

    model = thunderx2()
    kernel = _synthetic_kernel(512)
    plain_us = _timeit(lambda: analyze_kernel(kernel, model), repeats=5,
                       warmup=2)
    diag_us = _timeit(lambda: analyze_kernel(kernel, model, diagnose=True),
                      repeats=5, warmup=2)
    analysis = analyze_kernel(kernel, model, diagnose=True)
    codes = sorted({f.code for f in analysis.findings})
    overhead = diag_us / max(plain_us, 1e-9)
    _row("diagnostics", diag_us,
         f"plain_us={plain_us:.1f};overhead={overhead:.3f}x;"
         f"findings={len(analysis.findings)};codes={'|'.join(codes)};n=512")

    path = Path(__file__).resolve().parent.parent / "BENCH_analysis.json"
    doc = {"benchmark": "analysis", "entries": []}
    if path.exists():
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError:
            pass
    doc["entries"].append({
        "bench": "diagnostics", "n": 512,
        "plain_us": round(plain_us, 1), "diagnose_us": round(diag_us, 1),
        "overhead": round(overhead, 4),
        "findings": len(analysis.findings), "codes": codes,
    })
    path.write_text(json.dumps(doc, indent=2) + "\n")


def ibench_pipeline() -> None:
    import jax.numpy as jnp
    from repro.core.bench import populate_entry

    for name, op in [("add", lambda x: x + 1.0),
                     ("exp", jnp.exp),
                     ("matmul_chain", lambda x: x @ x * 1e-2)]:
        t0 = time.perf_counter()
        result, _ = populate_entry(name, op, shape=(64, 64),
                                   chain_length=16, n_parallel=2)
        us = (time.perf_counter() - t0) * 1e6
        _row(f"ibench_{name}", us,
             f"lat={result.latency_us:.2f}us;tput={result.inverse_throughput_us:.2f}us;"
             f"ilp={result.ilp_speedup:.2f}")


def hlo_roofline() -> None:
    import jax
    import jax.numpy as jnp
    from repro.core.hlo import roofline_from_compiled, hlo_loop_carried

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y.sum()

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.bfloat16),
        jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)).compile()
    us = _timeit(lambda: roofline_from_compiled(compiled, name="bench"))
    rep = roofline_from_compiled(compiled, name="bench",
                                 model_flops=2 * 128 ** 3 * 8)
    _row("hlo_roofline", us,
         f"dominant={rep.dominant};useful={rep.useful_ratio:.2f};"
         f"chains={len(hlo_loop_carried(compiled).chains)}")


def train_step_tiny() -> None:
    import jax
    from repro.configs import RunConfig, get_config, tiny_variant
    from repro.data import make_batch
    from repro.train import init_train_state, make_train_step

    cfg = tiny_variant(get_config("tinyllama-1.1b"))
    run = RunConfig(attention_impl="chunked", attention_chunk=64,
                    remat="full", zero=False)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, run), donate_argnums=())
    batch = {k: jax.numpy.asarray(v)
             for k, v in make_batch(cfg, 4, 128, 0, 0).items()}

    def go():
        _, m = step(state, batch)
        jax.block_until_ready(m["loss"])

    us = _timeit(go, repeats=3)
    _row("train_step_tiny", us, f"tok_per_s={4 * 128 / (us / 1e6):,.0f}")


def decode_step_tiny() -> None:
    import jax
    import jax.numpy as jnp
    from repro.configs import RunConfig, get_config, tiny_variant
    from repro.models import decode_step, init_params, prefill

    cfg = tiny_variant(get_config("tinyllama-1.1b"))
    run = RunConfig(attention_impl="chunked", attention_chunk=64, remat="none",
                    zero=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 63), 0, cfg.vocab)
    _, cache = prefill(params, cfg, run, tokens)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, run, c, t))
    tok = tokens[:, -1:]

    def go():
        logits, _ = step(params, cache, tok)
        jax.block_until_ready(logits)

    us = _timeit(go, repeats=3)
    _row("decode_step_tiny", us, f"tok_per_s={4 / (us / 1e6):,.0f}")


def main(argv=None) -> None:
    import sys

    names = sys.argv[1:] if argv is None else list(argv)
    table = {fn.__name__: fn for fn in (
        table1_gauss_seidel, table2_tx2_detail, analyzer_throughput,
        analyzer_scaling, scheduler_balance, analysis_service, resilience,
        sim_steadystate, diagnostics, ibench_pipeline, hlo_roofline,
        train_step_tiny,
        decode_step_tiny)}
    unknown = [n for n in names if n not in table]
    if unknown:
        raise SystemExit(
            f"unknown benchmark(s) {unknown}; known: {sorted(table)}")
    print("name,us_per_call,derived")
    for name, fn in table.items():
        if not names or name in names:
            fn()


if __name__ == "__main__":
    main()
