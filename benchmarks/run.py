"""Benchmark harness: one function per paper table/figure + framework
benches.  Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  table1_gauss_seidel  — paper Table I: TP/LCD/CP on TX2/CLX/ZEN vs. published
  table2_tx2_detail    — paper Table II: TX2 port pressures
  analyzer_throughput  — analysis cost per instruction form (tool perf)
  ibench_pipeline      — §II-B semi-automatic benchmark pipeline on jnp ops
  hlo_roofline         — HLO parse + three-term roofline on a compiled step
  train_step_tiny      — end-to-end tiny train step wall time
  decode_step_tiny     — end-to-end tiny decode step wall time
"""

from __future__ import annotations

import time


def _timeit(fn, repeats=5, warmup=1):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")


def table1_gauss_seidel() -> None:
    from repro.core import (analyze_kernel, cascade_lake, parse_aarch64,
                            parse_x86, thunderx2, zen)
    from repro.core.validation import (GS_CLX_ASM, GS_TX2_ASM, GS_ZEN_ASM,
                                       TABLE1)

    for arch, asm, parse, model in [
        ("tx2", GS_TX2_ASM, parse_aarch64, thunderx2()),
        ("csx", GS_CLX_ASM, parse_x86, cascade_lake()),
        ("zen", GS_ZEN_ASM, parse_x86, zen()),
    ]:
        kernel = parse(asm, name="gauss-seidel")
        us = _timeit(lambda: analyze_kernel(kernel, model, unroll=4))
        a = analyze_kernel(kernel, model, unroll=4)
        row = TABLE1[arch]
        derived = (f"TP={a.tp_per_it:.2f}/{row.tp};LCD={a.lcd_per_it:.2f}/"
                   f"{row.lcd};CP={a.cp_per_it:.2f}/{row.cp};"
                   f"match={round(a.tp_per_it, 2) == row.tp and a.lcd_per_it == row.lcd and a.cp_per_it == row.cp}")
        _row(f"table1_{arch}", us, derived)


def table2_tx2_detail() -> None:
    from repro.core import analyze_kernel, parse_aarch64, thunderx2
    from repro.core.validation import GS_TX2_ASM

    kernel = parse_aarch64(GS_TX2_ASM)
    a = analyze_kernel(kernel, thunderx2(), unroll=4)
    us = _timeit(lambda: a.report())
    pp = {p: round(v / 4, 2) for p, v in a.tp.port_pressure.items() if v}
    _row("table2_tx2", us, ";".join(f"{p}={v}" for p, v in sorted(pp.items())))


def analyzer_throughput() -> None:
    from repro.core import analyze_kernel, parse_x86, cascade_lake
    from repro.core.validation import GS_CLX_ASM

    body = GS_CLX_ASM.replace("# OSACA-END", "") + "# OSACA-END"
    kernel = parse_x86(body)
    model = cascade_lake()
    us = _timeit(lambda: analyze_kernel(kernel, model, unroll=4))
    _row("analyzer_throughput", us,
         f"{us / len(kernel):.2f}us_per_instruction;n={len(kernel)}")


def ibench_pipeline() -> None:
    import jax.numpy as jnp
    from repro.core.bench import populate_entry

    for name, op in [("add", lambda x: x + 1.0),
                     ("exp", jnp.exp),
                     ("matmul_chain", lambda x: x @ x * 1e-2)]:
        t0 = time.perf_counter()
        result, entry = populate_entry(name, op, shape=(64, 64),
                                       chain_length=16, n_parallel=2)
        us = (time.perf_counter() - t0) * 1e6
        _row(f"ibench_{name}", us,
             f"lat={result.latency_us:.2f}us;tput={result.inverse_throughput_us:.2f}us;"
             f"ilp={result.ilp_speedup:.2f}")


def hlo_roofline() -> None:
    import jax
    import jax.numpy as jnp
    from repro.core.hlo import roofline_from_compiled, hlo_loop_carried

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y.sum()

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.bfloat16),
        jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)).compile()
    us = _timeit(lambda: roofline_from_compiled(compiled, name="bench"))
    rep = roofline_from_compiled(compiled, name="bench",
                                 model_flops=2 * 128 ** 3 * 8)
    _row("hlo_roofline", us,
         f"dominant={rep.dominant};useful={rep.useful_ratio:.2f};"
         f"chains={len(hlo_loop_carried(compiled).chains)}")


def train_step_tiny() -> None:
    import jax
    from repro.configs import RunConfig, get_config, tiny_variant
    from repro.data import make_batch
    from repro.train import init_train_state, make_train_step

    cfg = tiny_variant(get_config("tinyllama-1.1b"))
    run = RunConfig(attention_impl="chunked", attention_chunk=64,
                    remat="full", zero=False)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, run), donate_argnums=())
    batch = {k: jax.numpy.asarray(v)
             for k, v in make_batch(cfg, 4, 128, 0, 0).items()}

    def go():
        _, m = step(state, batch)
        jax.block_until_ready(m["loss"])

    us = _timeit(go, repeats=3)
    _row("train_step_tiny", us, f"tok_per_s={4 * 128 / (us / 1e6):,.0f}")


def decode_step_tiny() -> None:
    import jax
    import jax.numpy as jnp
    from repro.configs import RunConfig, get_config, tiny_variant
    from repro.models import decode_step, init_params, prefill

    cfg = tiny_variant(get_config("tinyllama-1.1b"))
    run = RunConfig(attention_impl="chunked", attention_chunk=64, remat="none",
                    zero=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 63), 0, cfg.vocab)
    _, cache = prefill(params, cfg, run, tokens)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, run, c, t))
    tok = tokens[:, -1:]

    def go():
        logits, _ = step(params, cache, tok)
        jax.block_until_ready(logits)

    us = _timeit(go, repeats=3)
    _row("decode_step_tiny", us, f"tok_per_s={4 / (us / 1e6):,.0f}")


def main() -> None:
    print("name,us_per_call,derived")
    table1_gauss_seidel()
    table2_tx2_detail()
    analyzer_throughput()
    ibench_pipeline()
    hlo_roofline()
    train_step_tiny()
    decode_step_tiny()


if __name__ == "__main__":
    main()
