"""Quickstart: the paper's workflow end to end in ~40 lines.

1. Analyze an assembly loop kernel (throughput / CP / LCD) — the OSACA
   reproduction — and print the Table-II-style report.
2. Run the same methodology on a compiled JAX step: three-term roofline +
   loop-carried chains on TPU-target HLO.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import analyze_kernel, parse_aarch64, thunderx2
from repro.core.hlo import hlo_loop_carried, roofline_from_compiled
from repro.core.validation import GS_TX2_ASM

# -- 1. Assembly analysis (paper §II, Tables I/II) ---------------------------

kernel = parse_aarch64(GS_TX2_ASM, name="gauss-seidel")
analysis = analyze_kernel(kernel, thunderx2(), unroll=4)
print(analysis.report())
print()
print("runtime bracket [TP, CP] =",
      f"[{analysis.tp_per_it:.2f}, {analysis.cp_per_it:.2f}] cy/it,",
      f"expected (LCD) = {analysis.lcd_per_it:.2f} cy/it",
      "(paper measures 18.50)")

# -- 2. The same idea on XLA HLO (DESIGN.md §3) ------------------------------


def step(x, w1, w2):
    def layer(c, _):
        return jnp.tanh(c @ w1) @ w2, None
    y, _ = jax.lax.scan(layer, x, None, length=8)
    return y.sum()


compiled = jax.jit(step).lower(
    jax.ShapeDtypeStruct((256, 512), jnp.bfloat16),
    jax.ShapeDtypeStruct((512, 512), jnp.bfloat16),
    jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)).compile()

report = roofline_from_compiled(compiled, name="8-layer-mlp",
                                model_flops=2 * 256 * 512 * 512 * 2 * 8)
print()
print(report.render())
print()
print(hlo_loop_carried(compiled).render())
