"""End-to-end driver: train a ~100M-parameter model for a few hundred steps.

Uses the full mamba2-130m architecture config (the smallest assigned arch,
130M params) with a reduced sequence length so it runs on this CPU container;
on a real pod the same driver scales via repro.launch.train --no-tiny with
the production mesh.  Checkpoints + restarts are exercised on the way.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse

from repro.configs import RunConfig, get_config
from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = get_config("mamba2-130m")  # 130M params, attention-free
    run = RunConfig(attention_impl="chunked", remat="full", zero=False,
                    learning_rate=6e-4, warmup_steps=50,
                    total_steps=args.steps)
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.0f}M params, "
          f"batch {args.global_batch} x seq {args.seq_len}")
    train_loop(cfg, run, steps=args.steps, global_batch=args.global_batch,
               seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
               checkpoint_every=100, log_every=10)


if __name__ == "__main__":
    main()
