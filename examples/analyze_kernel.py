"""Analyze an assembly file with the OSACA reproduction.

Usage:
  PYTHONPATH=src python examples/analyze_kernel.py <file.s> --arch tx2 [--unroll 4]

``--arch`` accepts any id or alias from the architecture registry
(``tx2``/``csx``/``zen``/``zen2``/``n1``, ``cascadelake``, ``graviton2``, …);
``--format json`` (or the ``--json`` shorthand) emits the stable schema-v4
``AnalysisReport`` payload instead of the Table-II text report.
``--predictors tp,cp`` restricts the analysis to a subset of the four
predictors (``tp``/``cp``/``lcd``/``sim``).  Bottleneck diagnostics
(LCD chains, port hotspots, DB coverage gaps, window limits, unroll advice)
are on by default; ``--no-diagnose`` turns them off.

Markers: wrap the loop body in ``# OSACA-BEGIN`` / ``# OSACA-END`` comments,
use IACA byte markers, or let the tool auto-detect the innermost loop.
Without a file argument, analyzes the built-in Gauss-Seidel kernels on
*every* machine model and prints the three-way comparison — throughput
bounds, the window-limited OoO point prediction, and the critical path —
before the detailed report for ``--arch``.
"""

import argparse

from repro.api import analyze, asm_arch_ids, get_arch


def _summary_rows(report):
    """(label, cy/it) rows: the bracket plus the point predictions inside."""
    rows = [("TP (optimistic)", report.tp_block / report.unroll),
            ("TP (balanced)", report.tp_balanced_block / report.unroll)]
    if report.lcd_block:
        rows.append(("LCD (expected)", report.lcd_per_it))
    if report.sim_per_it is not None:
        rows.append(("sim (point)", report.sim_per_it))
    rows.append(("CP (upper)", report.cp_per_it))
    return rows


def _print_footer(report) -> None:
    ghz = report.frequency_ghz
    for label, cy in _summary_rows(report):
        print(f"{label:>16}: {cy:7.2f} cy/it = {cy / ghz:7.2f} ns/it "
              f"@ {ghz} GHz")


def _print_all_arches(unroll, predictors) -> None:
    print(f"{'arch':>6}  {'TP(opt)':>8}  {'TP(bal)':>8}  {'sim':>8}  "
          f"{'CP':>8}   cy/it on the built-in Gauss-Seidel kernel")
    for arch_id in asm_arch_ids():
        spec = get_arch(arch_id)
        if spec.sample_asm is None:
            continue
        report = analyze(spec.sample_asm, arch=arch_id, unroll=unroll,
                         name="gauss-seidel", predictors=predictors)
        sim = (f"{report.sim_per_it:8.2f}" if report.sim_per_it is not None
               else f"{'-':>8}")
        print(f"{arch_id:>6}  {report.tp_block / report.unroll:8.2f}  "
              f"{report.tp_balanced_block / report.unroll:8.2f}  {sim}  "
              f"{report.cp_per_it:8.2f}")
    print()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("file", nargs="?", default=None)
    ap.add_argument("--arch", default="tx2",
                    help=f"architecture id or alias; ids: "
                         f"{', '.join(asm_arch_ids())}")
    ap.add_argument("--unroll", type=int, default=4)
    ap.add_argument("--format", default="text",
                    choices=("text", "json", "markdown"))
    ap.add_argument("--json", action="store_true",
                    help="shorthand for --format json (full schema-v4 report)")
    ap.add_argument("--predictors", default="",
                    help="comma-separated subset of tp,cp,lcd,sim "
                         "(empty = all four)")
    ap.add_argument("--no-diagnose", dest="diagnose", action="store_false",
                    help="skip the bottleneck-diagnostics pass")
    args = ap.parse_args()
    if args.json:
        args.format = "json"

    try:
        spec = get_arch(args.arch)
    except ValueError as exc:
        ap.error(str(exc))
    predictors = (tuple(p.strip() for p in args.predictors.split(",")
                        if p.strip()) or None)
    if args.file:
        with open(args.file) as f:
            asm = f.read()
        name = args.file
    else:
        if spec.sample_asm is None:
            ap.error(f"arch '{spec.id}' has no built-in kernel; pass a file")
        asm, name = spec.sample_asm, "gauss-seidel"
        if args.format == "text":
            _print_all_arches(args.unroll, predictors)

    try:
        report = analyze(asm, arch=spec.id, unroll=args.unroll, name=name,
                         predictors=predictors, diagnose=args.diagnose)
    except ValueError as exc:  # bad --predictors entry
        ap.error(str(exc))
    print(report.render(args.format))
    if args.format != "text" or report.kind != "asm":
        return  # HLO reports are already in seconds; no cycle→ns footer
    print()
    _print_footer(report)


if __name__ == "__main__":
    main()
