"""Analyze an assembly file with the OSACA reproduction.

Usage:
  PYTHONPATH=src python examples/analyze_kernel.py <file.s> --arch tx2 [--unroll 4]

Markers: wrap the loop body in ``# OSACA-BEGIN`` / ``# OSACA-END`` comments,
use IACA byte markers, or let the tool auto-detect the innermost loop.
Without a file argument, analyzes the built-in Gauss-Seidel kernels.
"""

import argparse

from repro.core import (analyze_kernel, cascade_lake, parse_aarch64, parse_x86,
                        thunderx2, zen)
from repro.core.validation import GS_CLX_ASM, GS_TX2_ASM, GS_ZEN_ASM

MODELS = {"tx2": thunderx2, "csx": cascade_lake, "zen": zen}
BUILTIN = {"tx2": GS_TX2_ASM, "csx": GS_CLX_ASM, "zen": GS_ZEN_ASM}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("file", nargs="?", default=None)
    ap.add_argument("--arch", default="tx2", choices=sorted(MODELS))
    ap.add_argument("--unroll", type=int, default=4)
    args = ap.parse_args()

    model = MODELS[args.arch]()
    asm = open(args.file).read() if args.file else BUILTIN[args.arch]
    parse = parse_aarch64 if model.isa == "aarch64" else parse_x86
    kernel = parse(asm, name=args.file or "gauss-seidel")
    analysis = analyze_kernel(kernel, model, unroll=args.unroll)
    print(analysis.report())
    bracket = analysis.prediction_bracket()
    print()
    ghz = model.frequency_ghz
    for name, cy in bracket.items():
        print(f"{name:>16}: {cy:7.2f} cy/it = {cy / ghz:7.2f} ns/it @ {ghz} GHz")


if __name__ == "__main__":
    main()
