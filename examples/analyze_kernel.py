"""Analyze an assembly file with the OSACA reproduction.

Usage:
  PYTHONPATH=src python examples/analyze_kernel.py <file.s> --arch tx2 [--unroll 4]

``--arch`` accepts any id or alias from the architecture registry
(``tx2``/``csx``/``zen``/``zen2``/``n1``, ``cascadelake``, ``graviton2``, …);
``--format json`` emits the stable ``AnalysisReport`` schema instead of the
Table-II text report.

Markers: wrap the loop body in ``# OSACA-BEGIN`` / ``# OSACA-END`` comments,
use IACA byte markers, or let the tool auto-detect the innermost loop.
Without a file argument, analyzes the built-in Gauss-Seidel kernels.
"""

import argparse

from repro.api import analyze, asm_arch_ids, get_arch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("file", nargs="?", default=None)
    ap.add_argument("--arch", default="tx2",
                    help=f"architecture id or alias; ids: "
                         f"{', '.join(asm_arch_ids())}")
    ap.add_argument("--unroll", type=int, default=4)
    ap.add_argument("--format", default="text",
                    choices=("text", "json", "markdown"))
    args = ap.parse_args()

    try:
        spec = get_arch(args.arch)
    except ValueError as exc:
        ap.error(str(exc))
    if args.file:
        with open(args.file) as f:
            asm = f.read()
        name = args.file
    else:
        if spec.sample_asm is None:
            ap.error(f"arch '{spec.id}' has no built-in kernel; pass a file")
        asm, name = spec.sample_asm, "gauss-seidel"

    report = analyze(asm, arch=spec.id, unroll=args.unroll, name=name)
    print(report.render(args.format))
    if args.format != "text" or report.kind != "asm":
        return  # HLO reports are already in seconds; no cycle→ns footer
    print()
    ghz = report.frequency_ghz
    for key, cy in report.prediction_bracket().items():
        print(f"{key:>16}: {cy:7.2f} cy/it = {cy / ghz:7.2f} ns/it @ {ghz} GHz")


if __name__ == "__main__":
    main()
