"""Batched serving demo: continuous batching through prefill + decode.

Run:  PYTHONPATH=src python examples/serve_demo.py [--arch qwen3-8b]
(all archs run as tiny variants on CPU; --no-tiny for the full config)
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs, tiny_variant
from repro.models import init_params
from repro.serving import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = tiny_variant(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=args.batch_size)

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, size=rng.integers(8, 48)))
               for _ in range(args.requests)]
    frontend = None
    if cfg.frontend != "none":
        frontend = jax.numpy.ones(
            (args.batch_size, cfg.frontend_len, cfg.d_model), jax.numpy.bfloat16)

    t0 = time.time()
    results = engine.generate(prompts, max_new_tokens=args.max_new_tokens,
                              frontend=frontend)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"{cfg.name}: {len(results)} requests -> {n_tok} tokens "
          f"in {dt:.1f}s ({n_tok / dt:.1f} tok/s, "
          f"batch={args.batch_size} continuous)")
    for r in results[:5]:
        print(f"  req {r.request_id} (prompt {len(r.prompt)} toks): "
              f"{r.tokens[:10]}...")


if __name__ == "__main__":
    main()
