"""Min-max port-assignment scheduler: differential tests against the
brute-force enumeration oracle (and scipy's LP when present), bound ordering
on the example kernels, and the explicit-per-port equivalence guarantee."""

import random

import pytest

from repro.core import analyze_kernel, cascade_lake, parse_aarch64, parse_x86, thunderx2, zen
from repro.core.analysis import AnalysisReport
from repro.core.analysis.scheduler import (balance_from_costs,
                                           brute_force_min_max,
                                           gather_classes, linprog_min_max,
                                           min_max_load)
from repro.core.machine import DBEntry, MachineModel, neoverse_n1, uops_entry, zen2
from repro.core.validation import GS_CLX_ASM, GS_TX2_ASM, GS_ZEN_ASM

ALL_MODELS = [thunderx2, cascade_lake, zen, zen2, neoverse_n1]

EXAMPLE_KERNELS = [
    (GS_TX2_ASM, parse_aarch64, thunderx2),
    (GS_TX2_ASM, parse_aarch64, neoverse_n1),
    (GS_CLX_ASM, parse_x86, cascade_lake),
    (GS_ZEN_ASM, parse_x86, zen),
    (GS_ZEN_ASM, parse_x86, zen2),
]


# -- bound structure on the example kernels -----------------------------------


@pytest.mark.parametrize("asm,parse,mk", EXAMPLE_KERNELS)
def test_balanced_between_pinned_max_and_optimistic(asm, parse, mk):
    """max single-port pinned load <= balanced <= optimistic, everywhere."""
    model = mk()
    analysis = analyze_kernel(parse(asm, name="gs"), model, unroll=4)
    tp = analysis.tp
    assert tp.balanced_throughput <= tp.block_throughput + 1e-12
    classes = gather_classes(model.resolve_kernel(analysis.kernel))
    pinned_max = max((cy for eligible, cy in classes.items()
                      if len(eligible) == 1), default=0.0)
    assert tp.balanced_throughput >= pinned_max - 1e-12
    # Total work is conserved by the assignment.
    assert sum(tp.balanced_port_load.values()) == \
        pytest.approx(sum(tp.port_pressure.values()))
    # The bound is the max of the per-port loads it reports.
    assert tp.balanced_throughput == \
        pytest.approx(max(tp.balanced_port_load.values()))


@pytest.mark.parametrize("asm,parse,mk", EXAMPLE_KERNELS)
def test_balanced_matches_oracle_on_example_kernels(asm, parse, mk):
    model = mk()
    costs = model.resolve_kernel(parse(asm, name="gs"))
    schedule = balance_from_costs(costs, model.ports)
    oracle = brute_force_min_max(gather_classes(costs))
    assert schedule.bound == pytest.approx(oracle, abs=1e-9)


def test_tx2_balanced_shifts_alu_work_off_fp_ports():
    """The headline effect: TX2 integer ALU µ-ops (P0/P1/P2) escape to P2
    when P0/P1 are saturated by FP — uniform splitting cannot see this."""
    model = thunderx2()
    analysis = analyze_kernel(parse_aarch64(GS_TX2_ASM, name="gs"), model,
                              unroll=4)
    assert analysis.tp_per_it == pytest.approx(2.458, abs=5e-3)
    assert analysis.tp_balanced_per_it == pytest.approx(2.125, abs=1e-9)
    load = analysis.tp.balanced_port_load
    assert load["P2"] == pytest.approx(4.0)  # all 4 ALU µ-ops pushed to P2
    assert load["P0"] == load["P1"] == pytest.approx(8.5)


# -- differential: randomized instances vs. the oracle ------------------------


def _random_classes(rng, n_ports, n_classes):
    ports = [f"P{i}" for i in range(n_ports)]
    classes = {}
    for _ in range(n_classes):
        k = rng.randint(1, n_ports)
        eligible = frozenset(rng.sample(ports, k))
        classes[eligible] = classes.get(eligible, 0.0) + rng.randint(1, 8) / 2
    return ports, classes


@pytest.mark.parametrize("seed", range(40))
def test_min_max_load_matches_oracle_randomized(seed):
    rng = random.Random(seed)
    ports, classes = _random_classes(rng, rng.randint(2, 7), rng.randint(1, 9))
    schedule = min_max_load(classes, ports)
    assert schedule.bound == pytest.approx(brute_force_min_max(classes),
                                           abs=1e-9)
    # Per-port loads are a certificate: conserve work, never exceed the bound.
    assert sum(schedule.port_load.values()) == \
        pytest.approx(sum(classes.values()))
    assert max(schedule.port_load.values()) == pytest.approx(schedule.bound)
    # Water levels are non-increasing, outermost peel first.
    levels = [lv for lv, _ in schedule.levels]
    assert levels == sorted(levels, reverse=True)


@pytest.mark.parametrize("seed", range(12))
def test_min_max_load_matches_lp_randomized(seed):
    lp = linprog_min_max({frozenset(("A",)): 1.0})
    if lp is None:
        pytest.skip("scipy not available")
    rng = random.Random(1000 + seed)
    ports, classes = _random_classes(rng, rng.randint(2, 6), rng.randint(1, 7))
    schedule = min_max_load(classes, ports)
    assert schedule.bound == pytest.approx(linprog_min_max(classes), abs=1e-6)


def test_random_small_kernels_match_oracle():
    """Randomized small *kernels* end-to-end: parse -> resolve -> balance."""
    model = thunderx2()
    rng = random.Random(7)
    ops = ["fadd d{a}, d{b}, d{c}", "fmul d{a}, d{b}, d{c}",
           "add x{a}, x{b}, 8", "ldr d{a}, [x{b}, 8]",
           "str d{a}, [x{b}], 8", "cmp x{a}, x{b}"]
    for _ in range(15):
        lines = [rng.choice(ops).format(a=rng.randint(0, 7),
                                        b=rng.randint(0, 7),
                                        c=rng.randint(0, 7))
                 for _ in range(rng.randint(1, 12))]
        kernel = parse_aarch64(
            "# OSACA-BEGIN\n" + "\n".join(lines) + "\n# OSACA-END")
        costs = model.resolve_kernel(kernel)
        schedule = balance_from_costs(costs, model.ports)
        assert schedule.bound == pytest.approx(
            brute_force_min_max(gather_classes(costs)), abs=1e-9)


# -- explicit per-port DBs: balanced degenerates to optimistic ----------------


def test_explicit_per_port_db_gives_balanced_equals_optimistic():
    """A model whose entries pin µ-ops to explicit ports (pressure floats,
    no uops) has no assignment freedom: balanced == optimistic."""
    model = MachineModel(
        name="pinned", isa="aarch64", ports=("P0", "P1"),
        db={
            "fadd:fff": DBEntry(latency=2.0, pressure={"P0": 1.0}),
            "fmul:fff": DBEntry(latency=3.0, pressure={"P0": 0.5, "P1": 0.5}),
        },
        load_entry=DBEntry(latency=4.0, pressure={"P1": 1.0}),
        store_entry=DBEntry(latency=4.0, pressure={"P1": 1.0}),
    )
    kernel = parse_aarch64(
        "# OSACA-BEGIN\nfadd d0, d1, d2\nfmul d3, d0, d4\n"
        "fadd d5, d3, d6\n# OSACA-END")
    analysis = analyze_kernel(kernel, model)
    assert analysis.tp.balanced_throughput == \
        pytest.approx(analysis.tp.block_throughput)
    assert analysis.tp.balanced_port_load == \
        pytest.approx(analysis.tp.port_pressure)


def test_uops_entry_pressure_matches_uniform_split():
    entry = uops_entry(4.0, [(1.0, ("P0", "P1")), (1.0, ("P4",))])
    assert entry.pressure == {"P0": 0.5, "P1": 0.5, "P4": 1.0}
    assert entry.uops == ((1.0, ("P0", "P1")), (1.0, ("P4",)))
    with pytest.raises(ValueError, match="empty eligible port set"):
        uops_entry(1.0, [(1.0, ())])


def test_combined_with_merges_uops_and_pressure():
    a = uops_entry(4.0, [(1.0, ("P0", "P1"))])
    b = DBEntry(latency=6.0, pressure={"P2": 0.5, "P3": 0.5})
    merged = a.combined_with(b)
    assert merged.pressure == {"P0": 0.5, "P1": 0.5, "P2": 0.5, "P3": 0.5}
    # The pressure-only side joins as pinned single-port µ-ops.
    assert merged.uops == ((1.0, ("P0", "P1")), (0.5, ("P2",)), (0.5, ("P3",)))
    # Two pressure-only entries combine without inventing µ-ops.
    assert b.combined_with(b).uops is None


# -- report schema v2 ---------------------------------------------------------


def test_report_carries_balanced_bound_and_v1_compat():
    from repro.api import analyze

    report = analyze(GS_TX2_ASM, arch="tx2", unroll=4, name="gs")
    data = report.to_dict()
    assert data["schema_version"] == 4
    assert data["tp_balanced_block"] == pytest.approx(8.5)
    assert data["balanced_bottleneck"] in ("P0", "P1")
    restored = AnalysisReport.from_dict(data)
    assert restored.to_dict() == data

    # A v1 payload (no scheduler fields) loads with balanced == optimistic.
    v1 = {k: v for k, v in data.items()
          if k not in ("tp_balanced_block", "balanced_port_load",
                       "balanced_bottleneck")}
    v1["schema_version"] = 1
    legacy = AnalysisReport.from_dict(v1)
    assert legacy.tp_balanced_block == legacy.tp_block
    assert legacy.balanced_port_load == legacy.port_pressure
    assert legacy.balanced_bottleneck == legacy.bottleneck_port


def test_renderers_show_both_bounds():
    from repro.api import analyze

    report = analyze(GS_TX2_ASM, arch="tx2", unroll=4)
    text = report.render("text")
    assert "TP  (balanced)" in text and "balanced port load" in text
    assert "uniform split" in text
    md = report.render("markdown")
    assert "**TP** (balanced)" in md and "`P2`=4.00" in md
