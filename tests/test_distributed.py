"""Sharding-rule unit tests + a real multi-device dry-run smoke test.

The smoke test runs ``repro.launch.dryrun`` machinery in a subprocess with 16
forced host devices and a scaled-down mesh — proving lower+compile+roofline
works end-to-end with SPMD partitioning without the 512-device cost."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import RunConfig, get_config, tiny_variant
from repro.distributed import MeshContext
from repro.distributed.sharding import _sanitize, spec_for_path


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def ctx(shape):
    return MeshContext.__new__(MeshContext), shape  # not used directly


def make_ctx(shape):
    c = MeshContext.__new__(MeshContext)
    c.mesh = FakeMesh(shape)
    c.data_axes = tuple(a for a in ("pod", "data") if a in shape)
    c.model_axis = "model"
    return c


def test_sanitize_drops_nondivisible():
    c = make_ctx({"data": 4, "model": 8})
    spec = _sanitize(c, (16, 10), P("data", "model"))
    assert spec == P("data")  # 10 % 8 != 0 -> replicated


def test_sanitize_drops_missing_axis():
    c = make_ctx({"data": 4, "model": 4})
    spec = _sanitize(c, (16, 16), P(("pod", "data"), "model"))
    assert spec == P("data", "model")


def test_param_rules():
    assert spec_for_path(("embed",), (1000, 64)) == P("model", None)
    assert spec_for_path(("layers", "attn", "wq"), (4, 64, 128)) == \
        P(None, None, "model")
    assert spec_for_path(("layers", "attn", "wo"), (4, 128, 64)) == \
        P(None, "model", None)
    assert spec_for_path(("layers", "moe", "moe_wi"), (4, 8, 64, 128)) == \
        P(None, "model", None, None)
    assert spec_for_path(("final_norm",), (64,)) == P()


DRYRUN_SMOKE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import RunConfig, SHAPES, get_config, tiny_variant
    from repro.configs.base import ShapeConfig
    from repro.core.hlo import roofline_from_compiled, hlo_loop_carried
    from repro.distributed import MeshContext, set_mesh_context
    from repro.launch.specs import batch_shardings, cache_shardings, input_specs
    from repro.train import make_train_step
    from repro.train.state import abstract_train_state, state_shardings

    # axis_types/AxisType landed after jax 0.4.37; Auto is the default
    # everywhere, so passing nothing is equivalent on every version.
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    ctx = MeshContext(mesh=mesh, data_axes=("data",), model_axis="model")
    set_mesh_context(ctx)

    cfg = tiny_variant(get_config("{arch}"))
    shape = ShapeConfig("smoke", seq_len=128, global_batch=8, kind="train")
    run = RunConfig(attention_impl="chunked", attention_chunk=64,
                    remat="full", zero=True, fsdp=True, seq_shard=True)
    specs = input_specs(cfg, shape)
    state = abstract_train_state(cfg)
    st_shard = state_shardings(state, ctx, run)
    bshard = batch_shardings(specs, ctx)
    step = make_train_step(cfg, run)
    lowered = jax.jit(step, in_shardings=(st_shard, bshard),
                      out_shardings=(st_shard, None),
                      donate_argnums=(0,)).lower(state, specs)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0
    rep = roofline_from_compiled(compiled, name="smoke")
    assert rep.num_partitions == 16
    assert rep.terms["MXU"] > 0 and rep.terms["HBM"] > 0
    lcd = hlo_loop_carried(compiled)
    print("SMOKE_OK", rep.dominant, len(lcd.chains))
""")


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "deepseek-moe-16b",
                                  "mamba2-130m"])
def test_dryrun_smoke_16dev(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", DRYRUN_SMOKE.format(arch=arch)],
        capture_output=True, text=True, timeout=540, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SMOKE_OK" in proc.stdout


def test_serve_engine_roundtrip():
    from repro.models import init_params
    from repro.serving import ServeEngine

    cfg = tiny_variant(get_config("tinyllama-1.1b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=2)
    prompts = [[1, 2, 3, 4], [5, 6, 7, 8, 9], [10, 11]]
    results = engine.generate(prompts, max_new_tokens=4)
    assert len(results) == 3
    assert all(len(r.tokens) == 4 for r in results)
    assert [r.request_id for r in results] == [0, 1, 2]
