"""Coverage for the remaining substrate: optimizer math, gradient
compression, dry-run cell helpers, specs, data pipeline prefetch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, SHAPES, get_config, list_archs, tiny_variant
from repro.configs.base import ShapeConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule, global_norm
from repro.optim.adamw import compress_int8, decompress_int8


def test_adamw_descends_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    for step in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, opt, metrics = adamw_update(
            params, grads, opt, lr=jnp.asarray(0.05),
            weight_decay=0.0, grad_clip=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.2
    assert int(opt.count) == 200


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(params, grads, opt, lr=jnp.asarray(1e-3),
                                 grad_clip=1.0)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_cosine_schedule_shape():
    lr0 = cosine_schedule(jnp.asarray(0), 1e-3, warmup=10, total=100)
    lr9 = cosine_schedule(jnp.asarray(9), 1e-3, warmup=10, total=100)
    lr_mid = cosine_schedule(jnp.asarray(55), 1e-3, warmup=10, total=100)
    lr_end = cosine_schedule(jnp.asarray(99), 1e-3, warmup=10, total=100)
    assert 0 < float(lr0) < float(lr9) <= 1e-3 + 1e-9
    assert float(lr_end) < float(lr_mid) < 1e-3


def test_int8_compression_roundtrip():
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 3.0
    q, scale = compress_int8(g)
    assert q.dtype == jnp.int8
    rec = decompress_int8(q, scale)
    # 8-bit symmetric quantization: error bounded by scale/2 per element.
    assert float(jnp.max(jnp.abs(rec - g))) <= float(scale) * 0.51
    # ~16x compression of the payload.
    assert q.nbytes * 4 == g.nbytes


def test_global_norm():
    tree = {"a": jnp.ones((3,)), "b": jnp.ones((4,))}
    assert float(global_norm(tree)) == pytest.approx(np.sqrt(7.0))


def test_train_step_with_grad_compression():
    """int8-compressed gradient sync still trains (loss finite, params move)."""
    from repro.train import init_train_state, train_step

    cfg = tiny_variant(get_config("tinyllama-1.1b"))
    run = RunConfig(attention_impl="chunked", attention_chunk=32,
                    remat="none", zero=False, grad_compression="int8",
                    warmup_steps=1, total_steps=10)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    state1, m1 = train_step(state, batch, cfg, run)
    assert np.isfinite(float(m1["loss"]))
    _, m2 = train_step(state1, batch, cfg, run)
    assert float(m2["loss"]) != float(m1["loss"])  # params moved


# -- dry-run helpers -----------------------------------------------------------


def test_skip_reasons():
    from repro.launch.dryrun import cell_skip_reason

    long = SHAPES["long_500k"]
    assert cell_skip_reason(get_config("yi-9b"), long) != ""
    assert cell_skip_reason(get_config("mamba2-130m"), long) == ""
    assert cell_skip_reason(get_config("zamba2-2.7b"), long) == ""
    assert cell_skip_reason(get_config("whisper-base"), SHAPES["decode_32k"]) == ""


def test_input_specs_cover_all_cells():
    from repro.launch.specs import input_specs, model_flops_estimate

    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.supports_long_context:
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            if shape.kind == "decode":
                assert "cache" in specs
                assert specs["tokens"].shape == (shape.global_batch, 1)
            else:
                total = specs["tokens"].shape[1] + (
                    cfg.frontend_len if cfg.frontend == "vision_stub" else 0)
                assert total == shape.seq_len
            assert model_flops_estimate(cfg, shape) > 0


def test_default_run_config_by_kind():
    from repro.launch.dryrun import default_run_config

    cfg = get_config("yi-9b")
    train = default_run_config(cfg, SHAPES["train_4k"])
    assert train.fsdp and train.seq_shard and train.remat == "full"
    decode = default_run_config(cfg, SHAPES["decode_32k"])
    assert not decode.fsdp and decode.remat == "none"


def test_data_pipeline_prefetch():
    from repro.data import DataPipeline

    cfg = tiny_variant(get_config("tinyllama-1.1b"))
    pipe = DataPipeline(cfg, batch=2, seq=16, seed=3)
    b1 = next(pipe)
    b2 = next(pipe)
    assert b1["tokens"].shape == (2, 16)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    pipe.close()


def test_vocab_padding_masked():
    """Padded vocab columns never win argmax / never contribute to CE."""
    from repro.models import forward_train, init_params
    from repro.models.transformer import lm_logits

    cfg = tiny_variant(get_config("mamba2-130m"))
    assert cfg.padded_vocab % 16 == 0
    params = init_params(cfg, jax.random.PRNGKey(0))
    run = RunConfig(remat="none", zero=False)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    hidden, _ = forward_train(params, cfg, run, tokens)
    logits = lm_logits(params, cfg, hidden)
    assert logits.shape[-1] == cfg.padded_vocab
    if cfg.padded_vocab > cfg.vocab:
        pad = np.asarray(logits[..., cfg.vocab:])
        assert (pad <= -1e29).all()
    assert int(jnp.argmax(logits, -1).max()) < cfg.vocab
