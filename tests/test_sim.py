"""Window-limited OoO simulator: the bracket invariant TP(balanced) <= sim
<= CP on the example kernels and randomized kernels across all five machine
models, window-parameter schema bounds, window mechanics (capacity actually
binds), schema v3 round-trips, and end-to-end wiring through the facade and
the serving path."""

import random

import pytest

from repro.core import (analyze_kernel, cascade_lake, parse_aarch64,
                        parse_x86, thunderx2, zen)
from repro.core.analysis import (AnalysisReport, analyze_kernel_bracket,
                                 normalize_predictors)
from repro.core.machine import WindowParams, neoverse_n1, zen2
from repro.core.registry import asm_arch_ids, get_arch
from repro.core.sim import simulate_kernel
from repro.core.validation import GS_CLX_ASM, GS_TX2_ASM, GS_ZEN_ASM

EXAMPLE_KERNELS = [
    ("tx2", GS_TX2_ASM, parse_aarch64, thunderx2),
    ("n1", GS_TX2_ASM, parse_aarch64, neoverse_n1),
    ("csx", GS_CLX_ASM, parse_x86, cascade_lake),
    ("zen", GS_ZEN_ASM, parse_x86, zen),
    ("zen2", GS_ZEN_ASM, parse_x86, zen2),
]

TOL = 1e-9


# -- window parameter schema (every asm arch ships a plausible window) --------


def test_every_asm_arch_defines_window_params():
    for arch_id in asm_arch_ids():
        model = get_arch(arch_id).model_factory()
        w = model.window
        assert w is not None, f"{arch_id} has no window parameters"
        for field in ("issue_width", "rob_size", "sched_size", "lsq_size",
                      "retire_width"):
            value = getattr(w, field)
            assert isinstance(value, int) and value > 0, \
                f"{arch_id}.{field} = {value!r}"
        assert w.issue_width <= w.retire_width <= w.rob_size, arch_id
        assert w.lsq_size <= w.sched_size <= w.rob_size, arch_id


@pytest.mark.parametrize("kw", [
    dict(issue_width=0),
    dict(rob_size=-1),
    dict(retire_width=2),       # retire < issue
    dict(rob_size=3),           # rob < retire
    dict(sched_size=200),       # sched > rob
    dict(lsq_size=80),          # lsq > sched
    dict(issue_width=2.0),      # non-integer
])
def test_window_params_validate_rejects_bad_bounds(kw):
    base = dict(issue_width=4, rob_size=128, sched_size=60, lsq_size=40,
                retire_width=4)
    base.update(kw)
    with pytest.raises((ValueError, TypeError)):
        WindowParams(**base).validate()


# -- the bracket invariant on the example kernels -----------------------------


@pytest.mark.parametrize("arch,asm,parse,mk", EXAMPLE_KERNELS)
@pytest.mark.parametrize("unroll", [1, 4])
def test_sim_inside_bracket_on_example_kernels(arch, asm, parse, mk, unroll):
    analysis = analyze_kernel(parse(asm, name="gs"), mk(), unroll=unroll)
    sim = analysis.sim
    assert sim is not None and sim.converged
    lo = analysis.tp.balanced_throughput
    hi = max(analysis.cp.length, lo)
    assert lo - TOL <= sim.cy_per_block <= hi + TOL
    # On the Gauss-Seidel kernels the window prediction is *strictly* inside
    # the bracket (no clamping needed): the simulator genuinely closes it.
    assert sim.clamped_to == ""
    assert lo < sim.raw_cy_per_block < hi
    assert sim.cy_per_block == sim.raw_cy_per_block


@pytest.mark.parametrize("arch,sim_per_it,limiter", [
    ("tx2", 18.0, "ports"),
    ("n1", 7.5, "dependencies"),
    ("csx", 14.0, "dependencies"),
    ("zen", 11.5, "dependencies"),
    ("zen2", 10.5, "dependencies"),
])
def test_sim_point_predictions_on_gauss_seidel(arch, sim_per_it, limiter):
    """Pinned steady-state predictions (4x unroll): regressions in dispatch,
    port arbitration, or retirement shift these immediately."""
    asm, parse, mk = {a: (s, p, m) for a, s, p, m in EXAMPLE_KERNELS}[arch]
    analysis = analyze_kernel(parse(asm, name="gs"), mk(), unroll=4)
    assert analysis.sim_per_it == pytest.approx(sim_per_it, abs=1e-9)
    assert analysis.sim.limiter == limiter
    assert analysis.sim.copies == 4  # steady already at the warmup exit


# -- randomized kernels x five arches -----------------------------------------

AARCH64_OPS = ["fadd d{a}, d{b}, d{c}", "fmul d{a}, d{b}, d{c}",
               "fdiv d{a}, d{b}, d{c}", "add x{a}, x{b}, 8",
               "ldr d{a}, [x{b}, 8]", "str d{a}, [x{b}], 8",
               "cmp x{a}, x{b}"]
X86_OPS = ["vaddsd %xmm{a}, %xmm{b}, %xmm{c}",
           "vmulsd %xmm{a}, %xmm{b}, %xmm{c}",
           "movsd 8(%rax,%rbx,8), %xmm{a}",
           "movsd %xmm{a}, 8(%rax,%rbx,8)",
           "addq $8, %rax", "cmpq %rbx, %rax"]


def _random_kernel(rng, isa):
    ops, parse = ((AARCH64_OPS, parse_aarch64) if isa == "aarch64"
                  else (X86_OPS, parse_x86))
    lines = [rng.choice(ops).format(a=rng.randint(0, 7), b=rng.randint(0, 7),
                                    c=rng.randint(0, 7))
             for _ in range(rng.randint(1, 14))]
    return parse("# OSACA-BEGIN\n" + "\n".join(lines) + "\n# OSACA-END",
                 name="rand")


ARCH_SEED = {"tx2": 100, "n1": 200, "csx": 300, "zen": 400, "zen2": 500}


@pytest.mark.parametrize("arch,mk", [("tx2", thunderx2), ("n1", neoverse_n1),
                                     ("csx", cascade_lake), ("zen", zen),
                                     ("zen2", zen2)])
@pytest.mark.parametrize("seed", range(8))
def test_sim_bracket_property_randomized(arch, mk, seed):
    """Property: for any kernel, the headline sim prediction lies inside
    [TP(balanced), max(TP, CP)] and the raw measurement never undercuts it
    by more than the clamp admits."""
    model = mk()
    rng = random.Random(seed * 31 + ARCH_SEED[arch])
    analysis = analyze_kernel(_random_kernel(rng, model.isa), model)
    sim = analysis.sim
    assert sim is not None
    lo = analysis.tp.balanced_throughput
    hi = max(analysis.cp.length, lo)
    assert lo - TOL <= sim.cy_per_block <= hi + TOL
    assert sim.raw_cy_per_block > 0.0
    # The clamp annotation is truthful.
    if sim.clamped_to == "":
        assert sim.cy_per_block == sim.raw_cy_per_block
    elif sim.clamped_to == "tp":
        assert sim.raw_cy_per_block < lo and sim.cy_per_block == lo
    else:
        assert sim.clamped_to == "cp"
        assert sim.raw_cy_per_block > hi and sim.cy_per_block == hi
    # Determinism: a second run reproduces the prediction bit-for-bit.
    again = analyze_kernel(_random_kernel(
        random.Random(seed * 31 + ARCH_SEED[arch]), model.isa), model)
    assert again.sim.cy_per_block == sim.cy_per_block
    assert again.sim.copies == sim.copies


# -- window mechanics: the capacities actually bind ---------------------------


def test_tiny_rob_throttles_independent_work():
    """64 independent (pipelined) fmuls: a 4-entry ROB serializes what a
    128-entry ROB overlaps, so the steady-state rate must degrade."""
    model = thunderx2()
    kernel = parse_aarch64(
        "# OSACA-BEGIN\n" +
        "\n".join(f"fmul d{i % 8}, d{8 + i % 8}, d{16 + i % 8}"
                  for i in range(64)) + "\n# OSACA-END")
    big = simulate_kernel(kernel, model, window=WindowParams(
        issue_width=4, rob_size=128, sched_size=60, lsq_size=36,
        retire_width=4))
    small = simulate_kernel(kernel, model, window=WindowParams(
        issue_width=1, rob_size=4, sched_size=2, lsq_size=2, retire_width=1))
    assert small.raw_cy_per_block > big.raw_cy_per_block * 1.5
    assert small.limiter in ("frontend", "rob", "scheduler")


def test_serial_chain_sim_tracks_latency_not_throughput():
    """A pure latency chain: the point prediction sits at the CP end of the
    bracket, far above the port bound."""
    model = thunderx2()
    kernel = parse_aarch64(
        "# OSACA-BEGIN\nfadd d0, d0, d1\nfadd d0, d0, d2\n"
        "fadd d0, d0, d3\n# OSACA-END")
    analysis = analyze_kernel(kernel, model)
    sim = analysis.sim
    assert sim.limiter == "dependencies"
    # Three chained 6-cycle fadds per copy: 18 cy/block in steady state.
    assert sim.cy_per_block == pytest.approx(analysis.cp.length, abs=TOL)
    assert sim.cy_per_block > 2 * analysis.tp.balanced_throughput


def test_simulate_kernel_requires_window_params():
    from repro.core.machine import DBEntry, MachineModel
    model = MachineModel(
        name="nowin", isa="aarch64", ports=("P0",),
        db={"fadd:fff": DBEntry(latency=2.0, pressure={"P0": 1.0})})
    kernel = parse_aarch64("# OSACA-BEGIN\nfadd d0, d1, d2\n# OSACA-END")
    with pytest.raises(ValueError, match="no window parameters"):
        simulate_kernel(kernel, model)
    # An explicit window= fills the gap for ad-hoc models.
    result = simulate_kernel(kernel, model, window=WindowParams(
        issue_width=2, rob_size=16, sched_size=8, lsq_size=4, retire_width=2))
    assert result.cy_per_block > 0.0


# -- predictor selection ------------------------------------------------------


def test_normalize_predictors_implication_rules():
    assert normalize_predictors(None) == ("tp", "cp", "lcd", "sim")
    assert normalize_predictors(()) == ("tp", "cp", "lcd", "sim")
    assert normalize_predictors(("cp",)) == ("tp", "cp")      # tp implied
    assert normalize_predictors(("sim",)) == ("tp", "cp", "sim")  # sim => cp
    assert normalize_predictors(["lcd", "tp"]) == ("tp", "lcd")
    with pytest.raises(ValueError, match="unknown predictor"):
        normalize_predictors(("tp", "vliw"))


def test_analyze_kernel_predictor_subsets():
    model = thunderx2()
    kernel = parse_aarch64(GS_TX2_ASM, name="gs")
    no_sim = analyze_kernel(kernel, model, unroll=4,
                            predictors=("tp", "cp", "lcd"))
    assert no_sim.sim is None and no_sim.cp is not None
    assert no_sim.stages_completed == ("resolve", "tp", "dag", "cp", "lcd")
    tp_only = analyze_kernel(kernel, model, predictors=("tp",))
    assert tp_only.cp is None and tp_only.lcd is None and tp_only.sim is None
    assert tp_only.stages_completed == ("resolve", "tp")
    sim_only = analyze_kernel(kernel, model, unroll=4, predictors=("sim",))
    assert sim_only.sim is not None and sim_only.cp is not None
    assert sim_only.lcd is None
    full = analyze_kernel(kernel, model, unroll=4)
    assert sim_only.sim.cy_per_block == full.sim.cy_per_block


def test_bracket_rung_skips_sim_only():
    analysis = analyze_kernel_bracket(
        parse_aarch64(GS_TX2_ASM, name="gs"), thunderx2(), 4)
    assert analysis.sim is None
    assert analysis.cp is not None and analysis.lcd is not None
    assert analysis.degradation == "bracket"


# -- report schema v3 ---------------------------------------------------------


def test_report_v3_roundtrip_carries_sim_fields():
    from repro.api import analyze

    report = analyze(GS_TX2_ASM, arch="tx2", unroll=4, name="gs")
    data = report.to_dict()
    assert data["schema_version"] == 4
    assert data["sim_block"] == pytest.approx(72.0)
    assert data["sim_converged"] is True
    assert data["sim_clamped"] == ""
    assert data["sim_limiter"] == "ports"
    assert data["sim_window"]["rob_size"] == 180
    assert report.sim_per_it == pytest.approx(18.0)
    restored = AnalysisReport.from_dict(data)
    assert restored.to_dict() == data


def test_report_v2_payload_loads_without_sim():
    from repro.api import analyze

    data = analyze(GS_TX2_ASM, arch="tx2", unroll=4).to_dict()
    v2 = {k: v for k, v in data.items() if not k.startswith("sim_")}
    v2["schema_version"] = 2
    v2.pop("stages_completed", None)
    legacy = AnalysisReport.from_dict(v2)
    assert legacy.sim_block is None and legacy.sim_per_it is None
    assert legacy.stages_completed == ("resolve", "tp", "dag", "cp", "lcd")
    # Absence is meaningful, not zero: renderers must omit the sim line.
    assert "sim (window OoO)" not in legacy.render("text")


def test_report_rejects_future_schema():
    from repro.api import analyze

    data = analyze(GS_TX2_ASM, arch="tx2").to_dict()
    data["schema_version"] = 5
    with pytest.raises(ValueError, match="newer than supported"):
        AnalysisReport.from_dict(data)


def test_renderers_show_sim_line():
    from repro.api import analyze

    report = analyze(GS_TX2_ASM, arch="tx2", unroll=4)
    text = report.render("text")
    assert "sim (window OoO)" in text and "point prediction" in text
    assert "**sim**" in report.render("markdown")
    no_sim = analyze(GS_TX2_ASM, arch="tx2", unroll=4,
                     predictors=("tp", "cp", "lcd"))
    assert "sim (window OoO)" not in no_sim.render("text")


# -- facade + serving wiring --------------------------------------------------


def test_api_analyze_predictors_reach_the_sim():
    from repro.api import analyze

    full = analyze(GS_TX2_ASM, arch="tx2", unroll=4)
    assert full.sim_block is not None
    subset = analyze(GS_TX2_ASM, arch="tx2", unroll=4,
                     predictors=("tp", "cp"))
    assert subset.sim_block is None and subset.cp_block > 0
    assert subset.lcd_block == 0.0
    with pytest.raises(ValueError, match="asm targets only"):
        analyze("HloModule m\n", arch="tpu-v5e", predictors=("tp",))


def test_service_serves_sim_and_keys_cache_by_predictors():
    from repro.serving.analysis import AnalysisRequest, AnalysisService

    service = AnalysisService()
    full = service.submit(AnalysisRequest(asm=GS_TX2_ASM, arch="tx2",
                                          unroll=4, name="gs"))
    assert full.ok and full.report.sim_block == pytest.approx(72.0)
    assert full.stages_completed == ("resolve", "tp", "dag", "cp", "lcd",
                                     "sim")
    bracket = service.submit(AnalysisRequest(
        asm=GS_TX2_ASM, arch="tx2", unroll=4, name="gs",
        predictors=("tp", "cp", "lcd")))
    assert bracket.ok and bracket.report.sim_block is None
    # Distinct predictor sets are distinct cache entries, not collisions.
    assert service.stats["hits"] == 0 and service.stats["misses"] == 2
    again = service.submit(AnalysisRequest(asm=GS_TX2_ASM, arch="tx2",
                                           unroll=4, name="gs"))
    assert again.report.sim_block == pytest.approx(72.0)
    assert service.stats["hits"] == 1


def test_sim_fault_degrades_to_bracket_rung():
    """A persistent sim-stage fault costs only the point prediction: the
    service answers from the bracket rung with both bounds intact."""
    from repro.serving.analysis import AnalysisRequest, AnalysisService
    from repro.serving.faults import FaultInjector, VirtualClock
    from repro.serving.resilience import ResilienceConfig

    clock = VirtualClock()
    service = AnalysisService(
        resilience=ResilienceConfig(clock=clock, sleep=clock.sleep,
                                    request_timeout_s=10.0),
        faults=FaultInjector(seed=0, rates={"stage:sim": 1.0}))
    resp = service.submit(AnalysisRequest(asm=GS_TX2_ASM, arch="tx2",
                                          unroll=4, name="gs"))
    assert resp.ok and resp.degraded
    assert resp.report.degradation == "bracket"
    assert resp.report.sim_block is None
    assert resp.report.cp_block > 0 and resp.report.lcd_block > 0
    assert resp.stages_completed == ("resolve", "tp", "dag", "cp", "lcd")
