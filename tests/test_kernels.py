"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles,
all in interpret mode (CPU executes the kernel bodies)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, flash_decode, fused_rmsnorm, ssd_chunk_dual
from repro.kernels import ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def _flash_expected(q, k, v, causal, window=0):
    b, s, h, d = q.shape
    g = h // k.shape[2]
    kq = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vq = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    out = ref.flash_attention_ref(qf, kq, vq, causal=causal, window=window)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,h,kh,d,bq,bk", [
    (128, 4, 4, 64, 64, 64),    # MHA
    (256, 4, 2, 64, 128, 128),  # GQA 2:1
    (256, 8, 1, 128, 128, 64),  # MQA, D=128, asymmetric blocks
])
def test_flash_attention_sweep(dtype, s, h, kh, d, bq, bk):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (2, s, h, d), dtype)
    k = jax.random.normal(keys[1], (2, s, kh, d), dtype)
    v = jax.random.normal(keys[2], (2, s, kh, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    exp = _flash_expected(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_non_causal_and_windowed():
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (1, 256, 2, 64))
    k = jax.random.normal(keys[1], (1, 256, 2, 64))
    v = jax.random.normal(keys[2], (1, 256, 2, 64))
    for kwargs in (dict(causal=False), dict(causal=True, window=64)):
        out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True,
                              **kwargs)
        exp = _flash_expected(q, k, v, kwargs.get("causal", True),
                              kwargs.get("window", 0))
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,h,kh,d,bk", [
    (512, 4, 4, 64, 128),
    (1024, 8, 2, 128, 256),
    (512, 4, 1, 64, 512),
])
def test_flash_decode_sweep(dtype, t, h, kh, d, bk):
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    b = 2
    q = jax.random.normal(keys[0], (b, 1, h, d), dtype)
    kc = jax.random.normal(keys[1], (b, t, kh, d), dtype)
    vc = jax.random.normal(keys[2], (b, t, kh, d), dtype)
    lengths = jnp.array([t // 3, t], jnp.int32)
    out = flash_decode(q, kc, vc, lengths, block_k=bk, interpret=True)
    g = h // kh
    qf = q[:, 0].reshape(b, kh, g, d).reshape(b * kh, g, d)
    kf = kc.transpose(0, 2, 1, 3).reshape(b * kh, t, d)
    vf = vc.transpose(0, 2, 1, 3).reshape(b * kh, t, d)
    exp = ref.decode_attention_ref(qf, kf, vf, jnp.repeat(lengths, kh))
    exp = exp.reshape(b, h, d)[:, None]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("q,p,n,h", [(32, 32, 16, 2), (64, 64, 32, 3),
                                     (128, 32, 64, 1)])
def test_ssd_intra_chunk_sweep(q, p, n, h):
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    b, nc = 2, 2
    xdt = jax.random.normal(keys[0], (b, nc, h, q, p)) * 0.1
    cum = -jnp.cumsum(jax.random.uniform(keys[1], (b, nc, h, q)), axis=-1)
    bm = jax.random.normal(keys[2], (b, nc, q, n)) * 0.3
    cm = jax.random.normal(keys[3], (b, nc, q, n)) * 0.3
    y, st = ssd_chunk_dual(xdt, cum, bm, cm, interpret=True)
    ye, ste = ref.ssd_intra_chunk_ref(xdt, cum, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ste), rtol=1e-4, atol=1e-4)


def test_ssd_kernel_matches_model_reference():
    """Kernel-based chunked SSD == the model's jnp ssd_chunked path."""
    from repro.models.mamba2 import ssd_chunked

    keys = jax.random.split(jax.random.PRNGKey(4), 5)
    b, s, h, p, n, chunk = 2, 128, 2, 32, 16, 32
    x = jax.random.normal(keys[0], (b, s, h, p)) * 0.2
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(keys[2], (h,)) * 0.2)
    bm = jax.random.normal(keys[3], (b, s, n)) * 0.3
    cm = jax.random.normal(keys[4], (b, s, n)) * 0.3

    y_ref, final_ref = ssd_chunked(x, dt, A, bm, cm, chunk)

    # Assemble the same quantities through the kernel path.
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    cum = jnp.cumsum(dtc * A, axis=2)  # (b,nc,Q,h)
    xdt = (xc * dtc[..., None]).transpose(0, 1, 3, 2, 4)  # (b,nc,h,Q,p)
    cumh = cum.transpose(0, 1, 3, 2)  # (b,nc,h,Q)
    bmc = bm.reshape(b, nc, chunk, n)
    cmc = cm.reshape(b, nc, chunk, n)
    y_intra, states = ssd_chunk_dual(xdt, cumh, bmc, cmc, interpret=True)

    # Inter-chunk recurrence (identical to the model's).
    def body(h_prev, inp):
        cdecay, cstate = inp
        return cdecay[..., None, None] * h_prev + cstate, h_prev

    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b,nc,h)
    h_last, h_prevs = jax.lax.scan(
        body, jnp.zeros((b, h, n, p)),
        (jnp.moveaxis(chunk_decay, 1, 0),
         jnp.moveaxis(states.astype(jnp.float32), 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", cmc, jnp.exp(cum), h_prevs)
    y_kernel = (y_intra.transpose(0, 1, 3, 2, 4) + y_inter).reshape(b, s, h, p)

    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(final_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 128), (4, 32, 256), (3, 5, 64)])
def test_rmsnorm_sweep(dtype, shape):
    keys = jax.random.split(jax.random.PRNGKey(5), 2)
    x = jax.random.normal(keys[0], shape, dtype)
    w = jax.random.normal(keys[1], (shape[-1],), jnp.float32)
    out = fused_rmsnorm(x, w, interpret=True)
    exp = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_matches_model_attention():
    """Kernel output == the model's chunked_attention (the XLA fallback)."""
    from repro.models.layers import chunked_attention

    keys = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(keys[0], (2, 128, 4, 64))
    k = jax.random.normal(keys[1], (2, 128, 2, 64))
    v = jax.random.normal(keys[2], (2, 128, 2, 64))
    out_kernel = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                                 interpret=True)
    out_model = chunked_attention(q, k, v, chunk=64, causal=True)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=2e-5, atol=2e-5)
