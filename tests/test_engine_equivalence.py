"""Differential tests: batched array engine vs. retained reference engine.

The single-sweep LCD, shared-DAG CP, and memoized lookup must be *bit-identical*
to the seed implementation (kept in ``repro.core.analysis.reference``) on
randomized synthetic kernels mixing FP arithmetic, loads, plain and
writeback stores, and pointer bumps — plus a regression pin of the Table I
numbers on all three paper architectures.
"""

import random
import warnings

import pytest

from repro.core import analyze_kernel, analyze_kernels
from repro.core.analysis import clear_analysis_cache
from repro.core.analysis.critical_path import critical_path
from repro.core.analysis.lcd import loop_carried_dependencies
from repro.core.analysis.reference import (reference_critical_path,
                                           reference_loop_carried_dependencies)
from repro.core.isa import parse_aarch64, parse_x86
from repro.core.machine import cascade_lake, thunderx2, zen
from repro.core.machine.model import DBEntry, MachineModel
from repro.core.validation import GS_CLX_ASM, GS_TX2_ASM, GS_ZEN_ASM


def random_mixed_kernel(rng: random.Random) -> str:
    """Random TX2 kernel with loads, stores, writeback, and pointer bumps."""
    n = rng.randint(3, 24)
    lines = []
    for _ in range(n):
        roll = rng.random()
        d, a, b = rng.randint(0, 5), rng.randint(0, 5), rng.randint(0, 5)
        x = rng.randint(1, 4)
        if roll < 0.45:
            op = rng.choice(["fadd", "fmul"])
            lines.append(f"{op} d{d}, d{a}, d{b}")
        elif roll < 0.6:
            lines.append(f"ldr d{d}, [x{x}, {8 * rng.randint(0, 7)}]")
        elif roll < 0.7:
            lines.append(f"ldr d{d}, [x{x}], 8")  # post-index writeback load
        elif roll < 0.8:
            lines.append(f"str d{a}, [x{x}, {8 * rng.randint(0, 7)}]")
        elif roll < 0.9:
            lines.append(f"str d{a}, [x{x}], 8")  # post-index writeback store
        else:
            lines.append(f"add x{x}, x{x}, 8")
    return "\n".join(lines)


def mixed_kernel_cases(count: int = 80, seed: int = 7):
    rng = random.Random(seed)
    return [random_mixed_kernel(rng) for _ in range(count)]


def tx2_kernel(body: str):
    return parse_aarch64(f"# OSACA-BEGIN\n{body}\n# OSACA-END")


def assert_lcd_equal(got, want, body):
    assert got.longest == want.longest, body
    assert got.on_longest == want.on_longest, body
    assert len(got.chains) == len(want.chains), body
    for g, w in zip(got.chains, want.chains):
        assert g.length == w.length, body
        assert g.instr_indices == w.instr_indices, body
        assert g.carried_by == w.carried_by, body


@pytest.mark.parametrize("body", mixed_kernel_cases(80))
def test_batched_engine_matches_reference(body):
    kernel = tx2_kernel(body)
    model = thunderx2()

    ref_cp = reference_critical_path(kernel, model)
    ref_lcd = reference_loop_carried_dependencies(kernel, model)

    # Standalone entry points (own DAG builds).
    cp = critical_path(kernel, model)
    lcd = loop_carried_dependencies(kernel, model)
    assert cp.length == ref_cp.length, body
    assert cp.on_path == ref_cp.on_path, body
    assert [n.nid for n in cp.path] == [n.nid for n in ref_cp.path], body
    assert_lcd_equal(lcd, ref_lcd, body)

    # Shared single-DAG pipeline (dual-writeback views).
    a = analyze_kernel(kernel, model)
    assert a.cp.length == ref_cp.length, body
    assert a.cp.on_path == ref_cp.on_path, body
    assert_lcd_equal(a.lcd, ref_lcd, body)


@pytest.mark.parametrize("body", mixed_kernel_cases(20, seed=11))
def test_flags_and_store_forwarding_dag_builds(body):
    """The beyond-paper DAG options still build and stay forward-only."""
    from repro.core.analysis import build_dag

    kernel = tx2_kernel(body + "\nsubs x1, x1, 1\nbne .L0")
    dag = build_dag(kernel, thunderx2(), copies=2, model_flags=True,
                    model_store_forwarding=True)
    for src, succs in enumerate(dag.succs):
        for dst in succs:
            assert dst > src


# -- Table I regression pins (seed-engine values, all three arches) -----------

SEED_TABLE1 = {
    "tx2": (2.4583333333333335, 18.0, 25.0),
    "csx": (2.1875, 14.0, 18.0),
    "zen": (2.0, 11.5, 15.0),
}


@pytest.mark.parametrize("arch,asm,parse,model_fn", [
    ("tx2", GS_TX2_ASM, parse_aarch64, thunderx2),
    ("csx", GS_CLX_ASM, parse_x86, cascade_lake),
    ("zen", GS_ZEN_ASM, parse_x86, zen),
])
def test_table1_pinned_to_seed_engine(arch, asm, parse, model_fn):
    a = analyze_kernel(parse(asm, name="gauss-seidel"), model_fn(), unroll=4)
    tp, lcd, cp = SEED_TABLE1[arch]
    assert a.tp_per_it == tp
    assert a.lcd_per_it == lcd
    assert a.cp_per_it == cp


# -- batch API + caches -------------------------------------------------------


def test_analyze_kernels_batch_and_cache():
    clear_analysis_cache()
    model = thunderx2()
    k1 = tx2_kernel("fadd d0, d0, d1")
    k2 = tx2_kernel("fmul d2, d2, d3\nfadd d4, d2, d2")
    first = analyze_kernels([k1, k2, k1], model, unroll=2)
    assert first[0] is first[2]  # same text -> same cached Analysis
    assert first[0].lcd.longest == 6.0
    assert first[1].lcd.longest == 6.0
    # A re-parse of identical text still hits the cache.
    again = analyze_kernels([tx2_kernel("fadd d0, d0, d1")], model, unroll=2)
    assert again[0] is first[0]
    # Different unroll is a different key.
    other = analyze_kernels([k1], model, unroll=4)
    assert other[0] is not first[0]
    clear_analysis_cache()


def test_analyze_kernels_matches_analyze_kernel():
    clear_analysis_cache()
    model = thunderx2()
    kernels = [tx2_kernel(b) for b in mixed_kernel_cases(6, seed=13)]
    batch = analyze_kernels(kernels, model, unroll=1)
    for kernel, a in zip(kernels, batch):
        single = analyze_kernel(kernel, model, unroll=1)
        assert a.tp.block_throughput == single.tp.block_throughput
        assert a.cp.length == single.cp.length
        assert a.lcd.longest == single.lcd.longest


def test_lookup_warns_once_per_unknown_form():
    model = MachineModel(
        name="warn-once-test", isa="aarch64", ports=("P0",),
        db={}, load_entry=DBEntry(latency=1.0, pressure={"P0": 1.0}),
        store_entry=DBEntry(latency=1.0, pressure={"P0": 1.0}),
    )
    kernel = tx2_kernel("fadd d0, d1, d2\nfadd d3, d4, d5\nfmul d6, d7, d0")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        model.resolve_kernel(kernel)
        model.resolve_kernel(kernel)
    messages = [str(w.message) for w in caught]
    # Two distinct unknown forms -> exactly two warnings across both passes.
    assert len([m for m in messages if "fadd:fff" in m]) == 1
    assert len([m for m in messages if "fmul:fff" in m]) == 1


def test_lookup_memoization_reuses_parts():
    model = thunderx2()
    kernel = tx2_kernel("fadd d0, d1, d2\nfadd d3, d4, d5")
    c1, c2 = model.resolve_kernel(kernel)
    assert c1.entry is c2.entry  # memoized DB parts are shared
    assert c1.form is not c2.form  # per-instruction identity preserved
