"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with shape + finiteness assertions, plus prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, list_archs, tiny_variant
from repro.models import decode_step, forward_train, init_params, prefill
from repro.train import init_train_state, train_step

RUN = RunConfig(attention_impl="chunked", attention_chunk=32, remat="full",
                zero=False, warmup_steps=2, total_steps=10)
B, S = 2, 64


def make_batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend != "none":
        batch["frontend"] = 0.02 * jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module", params=list_archs())
def arch_setup(request):
    cfg = tiny_variant(get_config(request.param))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    return request.param, cfg, params, make_batch(cfg, key)


def test_forward_shapes_and_finite(arch_setup):
    name, cfg, params, batch = arch_setup
    hidden, extras = forward_train(params, cfg, RUN, batch["tokens"],
                                   frontend=batch.get("frontend"))
    expect_s = S + (cfg.frontend_len if cfg.frontend == "vision_stub" else 0)
    assert hidden.shape == (B, expect_s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))


def test_train_step_reduces_no_nans(arch_setup):
    name, cfg, params, batch = arch_setup
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    state, metrics = train_step(state, batch, cfg, RUN)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state.step) == 1
    # Second step with the same data must change the loss (params moved).
    _, metrics2 = train_step(state, batch, cfg, RUN)
    assert float(metrics2["loss"]) != float(metrics["loss"])


def test_decode_matches_prefill_logits(arch_setup):
    """Teacher-forced decode: logits at position t from decode_step must
    match prefill logits of the length-(t+1) prefix."""
    name, cfg, params, batch = arch_setup
    tokens = batch["tokens"]
    frontend = batch.get("frontend")

    full_logits, _ = prefill(params, cfg, RUN, tokens, frontend=frontend)
    # Prefill on the first S-1 tokens, then decode token S-1.
    short_logits, cache = prefill(params, cfg, RUN, tokens[:, :-1],
                                  frontend=frontend)
    # Decode caches are sized by prefill length; grow for one extra token.
    from repro.serving.engine import ServeEngine
    engine = ServeEngine(cfg, params, run=RUN, batch_size=B)
    cache = engine._grow_cache(cache, tokens.shape[1] + 4, B)
    step_logits, cache2 = decode_step(params, cfg, RUN, cache, tokens[:, -1:])

    a = np.asarray(full_logits[:, -1], np.float32)
    b = np.asarray(step_logits[:, 0], np.float32)
    # bf16 compute + MoE capacity semantics (prefill routes in large groups,
    # decode in single-token groups) allow small absolute deviations; the
    # serving-level invariant is agreement of the prediction.
    np.testing.assert_allclose(a, b, rtol=0, atol=1e-1)
    assert (np.argmax(a, -1) == np.argmax(b, -1)).all()
    expected_pos = tokens.shape[1] + (
        cfg.frontend_len if cfg.frontend == "vision_stub" else 0)
    assert int(cache2["pos"]) == expected_pos


def test_attention_impls_agree():
    cfg = tiny_variant(get_config("qwen3-8b"))
    params = init_params(cfg, jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    naive = RunConfig(attention_impl="naive", remat="none", zero=False)
    chunked = RunConfig(attention_impl="chunked", attention_chunk=16,
                        remat="none", zero=False)
    h1, _ = forward_train(params, cfg, naive, tokens)
    h2, _ = forward_train(params, cfg, chunked, tokens)
    # bf16 probabilities in the PV matmul (flash-style) => bf16-level agreement.
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), rtol=6e-2, atol=6e-2)


def test_moe_routing_respects_topk():
    from repro.models.moe import route_topk

    g, s, e, k, cap = 2, 16, 8, 2, 8
    logits = jax.random.normal(jax.random.PRNGKey(3), (g, s, e))
    dispatch, combine, aux = route_topk(logits, k, cap)
    # Each token occupies at most top_k expert slots.
    per_token = np.asarray(jnp.sum(dispatch, axis=(2, 3)))
    assert (per_token <= k + 1e-6).all()
    # No (expert, capacity-slot) pair receives two tokens within a group.
    per_slot = np.asarray(jnp.sum(dispatch, axis=1).max())
    assert per_slot <= 1 + 1e-6
    # Combine weights are within the simplex per token.
    cw = np.asarray(jnp.sum(combine, axis=(2, 3)))
    assert (cw <= 1 + 1e-5).all()
    assert float(aux) > 0


def test_mamba_chunked_equals_stepwise():
    """SSD chunked scan == sequential single-step recurrence."""
    from repro.models.mamba2 import ssd_chunked

    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 5)
    b, s, h, p, n, chunk = 1, 32, 2, 16, 8, 8
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, s, n)) * 0.3

    y_chunk, h_chunk = ssd_chunked(x, dt, A, bm, cm, chunk)

    hstate = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A)  # (b,h)
        xdt = x[:, t] * dt[:, t][..., None]  # (b,h,p)
        hstate = dA[..., None, None] * hstate + jnp.einsum(
            "bn,bhp->bhnp", bm[:, t], xdt)
        ys.append(jnp.einsum("bn,bhnp->bhp", cm[:, t], hstate))
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(hstate),
                               rtol=1e-4, atol=1e-4)


def test_param_counts_match_spec():
    """Full configs land near their nameplate sizes."""
    expectations = {
        "yi-9b": (8.0e9, 9.5e9),
        "tinyllama-1.1b": (0.95e9, 1.25e9),
        "starcoder2-15b": (14e9, 17e9),
        "qwen3-8b": (7.0e9, 9.0e9),
        "deepseek-moe-16b": (14e9, 18e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
        "mamba2-130m": (0.1e9, 0.17e9),
    }
    for name, (lo, hi) in expectations.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n / 1e9:.2f}B not in [{lo / 1e9}, {hi / 1e9}]"
