"""Bottleneck-diagnostics tests: the ``diagnose`` pass, the schema-v4
``findings`` field (absence vs empty), renderer sections, cache-key
participation, and the serving envelope pass-through."""

import json

import pytest

from repro.api import analyze
from repro.core.analysis import AnalysisReport, Finding, diagnose
from repro.core.analysis.analyze import (_cache_key, analyze_kernel,
                                         analyze_kernel_rung)
from repro.core.analysis.diagnostics import _sim_findings
from repro.core.isa import parse_aarch64
from repro.core.machine import thunderx2
from repro.core.machine.window import WindowParams
from repro.core.sim.engine import SimResult
from repro.core.validation import GS_TX2_ASM
from repro.serving.analysis import AnalysisRequest, AnalysisService


def _by_code(findings, code):
    return [f for f in findings if f.code == code]


@pytest.fixture(scope="module")
def gs_report():
    return analyze(GS_TX2_ASM, arch="tx2", unroll=4, name="gs",
                   diagnose=True)


# -- the acceptance kernel: Gauss-Seidel on ThunderX2 -------------------------


def test_lcd_bottleneck_names_the_fadd_fmul_chain(gs_report):
    (finding,) = _by_code(gs_report.findings, "LCD_BOTTLENECK")
    assert finding.severity == "warning"
    edges = finding.payload["edges"]
    # The recurrence is the fadd/fadd/fmul pattern, every member at 6 cy.
    assert {e["mnemonic"] for e in edges} == {"fadd", "fmul"}
    assert all(e["latency"] == pytest.approx(6.0) for e in edges)
    # Per-edge contributions are consistent with the LCD sweep: they sum to
    # the reported chain period (Table I: 18 cy/it at 4x unroll).
    assert sum(e["latency"] for e in edges) == pytest.approx(
        finding.payload["chain_cycles"])
    assert finding.payload["chain_cycles"] == pytest.approx(
        gs_report.lcd_block)
    assert finding.payload["per_iteration"] == pytest.approx(18.0)
    assert finding.payload["residual_cycles"] == 0.0
    assert finding.payload["dominates_throughput"] is True
    # Anchors mirror the edges (clickable source lines).
    assert finding.lines == tuple(e["line"] for e in edges)
    assert finding.instrs == tuple(e["index"] for e in edges)


def test_port_hotspot_and_unroll_advice(gs_report):
    (hotspot,) = _by_code(gs_report.findings, "PORT_HOTSPOT")
    # The FP pipes saturate, but the LCD chain is longer — info, not warning.
    assert set(hotspot.payload["hot_ports"]) == {"P0", "P1"}
    assert hotspot.payload["bound"] == pytest.approx(
        gs_report.tp_balanced_block)
    assert hotspot.severity == "info"
    assert hotspot.payload["dominates"] is False
    for cls in hotspot.payload["saturating_classes"]:
        assert set(cls["ports"]) <= {"P0", "P1"}

    (advice,) = _by_code(gs_report.findings, "UNROLL_ADVICE")
    assert advice.severity == "advice"
    assert advice.payload["ratio"] == pytest.approx(
        gs_report.cp_per_it / (gs_report.tp_balanced_block / 4))
    assert 2 <= advice.payload["suggested_unroll"] <= 8
    # The LCD floor is carried so nobody unrolls expecting TP-level speed.
    assert advice.payload["lcd_per_it"] == pytest.approx(18.0)


def test_findings_sorted_most_severe_first(gs_report):
    ranks = {"warning": 0, "advice": 1, "info": 2}
    sevs = [ranks[f.severity] for f in gs_report.findings]
    assert sevs == sorted(sevs)


def test_diagnose_deterministic(gs_report):
    again = analyze(GS_TX2_ASM, arch="tx2", unroll=4, name="gs",
                    diagnose=True)
    assert again.findings == gs_report.findings


# -- DB_COVERAGE_GAP + the recorded fallback state (was warn-once only) -------


def test_db_coverage_gap_promotes_default_fallbacks():
    kernel = parse_aarch64("frobnicate d0, d0, d1\nfadd d1, d1, d2",
                           name="gap")
    model = thunderx2()
    analysis = analyze_kernel(kernel, model, 1, diagnose=True)
    gaps = _by_code(analysis.findings, "DB_COVERAGE_GAP")
    assert len(gaps) == 1
    (gap,) = gaps
    assert gap.severity == "warning"
    assert gap.payload["form"].startswith("frobnicate:")
    assert gap.payload["arch"] == "tx2"
    assert gap.payload["count"] == 1
    # Satellite: the fallback is recorded per-model state, not only a
    # process-wide warn-once message.
    assert any(k.startswith("frobnicate:") for k in model.fallbacks)
    # Known forms never show up as gaps.
    assert not any("fadd" in g.payload["form"] for g in gaps)


def test_clean_kernel_has_no_coverage_gap(gs_report):
    assert not _by_code(gs_report.findings, "DB_COVERAGE_GAP")


# -- SIM_WINDOW_LIMITED / SIM_CLAMPED (emitter-level: GS is ports-limited) ----


class _SimStub:
    def __init__(self, sim):
        self.sim = sim


def _sim(**kw):
    base = dict(cy_per_block=40.0, raw_cy_per_block=40.0, copies=4,
                converged=True, clamped_to="", limiter="ports",
                window=WindowParams(issue_width=4, rob_size=180,
                                    sched_size=60, lsq_size=40,
                                    retire_width=4),
                port_busy={})
    base.update(kw)
    return SimResult(**base)


def test_sim_window_limited_names_resource_and_capacity():
    findings = _sim_findings(_SimStub(_sim(limiter="rob")))
    (f,) = _by_code(findings, "SIM_WINDOW_LIMITED")
    assert f.severity == "info"
    assert f.payload["capacity_field"] == "rob_size"
    assert f.payload["capacity"] == 180
    assert "re-order buffer" in f.message
    # ports/dependencies are not window resources — no finding.
    assert not _sim_findings(_SimStub(_sim(limiter="ports")))


def test_sim_clamped_reports_bracket_edge():
    sim = _sim(clamped_to="cp", raw_cy_per_block=55.0, cy_per_block=50.0)
    (f,) = _by_code(_sim_findings(_SimStub(sim)), "SIM_CLAMPED")
    assert f.payload["edge"] == "cp"
    assert f.payload["raw_block"] == pytest.approx(55.0)
    assert "CP upper bound" in f.message


def test_gs_sim_within_bracket_has_no_sim_findings(gs_report):
    assert not _by_code(gs_report.findings, "SIM_CLAMPED")
    assert not _by_code(gs_report.findings, "SIM_WINDOW_LIMITED")


# -- schema v4: round-trip, absence vs empty, legacy loads --------------------


def test_v4_roundtrip_with_findings_bit_identical(gs_report):
    data = gs_report.to_dict()
    assert data["schema_version"] == 4
    assert data["findings"] and isinstance(data["findings"], list)
    wire = json.loads(json.dumps(data))
    restored = AnalysisReport.from_dict(wire)
    assert restored.to_dict() == data
    assert restored.findings == gs_report.findings


def test_findings_absent_vs_empty():
    # diagnose=False → the pass never ran → None (serialized null) …
    plain = analyze(GS_TX2_ASM, arch="tx2", unroll=4)
    assert plain.findings is None
    assert plain.to_dict()["findings"] is None
    # … while a rung that ran the pass but had nothing to say returns ().
    kernel = parse_aarch64(GS_TX2_ASM, name="gs")
    parsed = analyze_kernel_rung(kernel, thunderx2(), 4, rung="parse_only",
                                 diagnose=True)
    assert parsed.findings == ()
    report = AnalysisReport.from_analysis(parsed)
    assert report.to_dict()["findings"] == []
    back = AnalysisReport.from_dict(report.to_dict())
    assert back.findings == ()


@pytest.mark.parametrize("version", [1, 2, 3])
def test_legacy_payloads_load_with_findings_none(version):
    data = analyze(GS_TX2_ASM, arch="tx2", unroll=4, diagnose=True).to_dict()
    legacy = {k: v for k, v in data.items() if k != "findings"}
    if version < 3:
        legacy = {k: v for k, v in legacy.items()
                  if not k.startswith("sim_")}
        legacy.pop("stages_completed", None)
    if version < 2:
        for k in ("tp_balanced_block", "balanced_port_load",
                  "balanced_bottleneck"):
            legacy.pop(k, None)
    legacy["schema_version"] = version
    report = AnalysisReport.from_dict(legacy)
    assert report.findings is None  # pre-v4: the pass did not exist


def test_future_schema_still_rejected(gs_report):
    data = gs_report.to_dict()
    data["schema_version"] = 5
    with pytest.raises(ValueError, match="newer than supported"):
        AnalysisReport.from_dict(data)


def test_finding_from_dict_tolerates_missing_optionals():
    f = Finding.from_dict({"code": "X", "severity": "info", "message": "m"})
    assert f.lines == () and f.instrs == () and f.payload == {}


# -- renderers ----------------------------------------------------------------


def test_text_renderer_has_diagnostics_section(gs_report):
    text = gs_report.render("text")
    assert "Diagnostics (" in text
    assert "LCD_BOTTLENECK" in text and "[warning]" in text
    # Without the pass, the section is omitted entirely (absence ≠ zero).
    plain = analyze(GS_TX2_ASM, arch="tx2", unroll=4)
    assert "Diagnostics" not in plain.render("text")


def test_markdown_renderer_has_diagnostics_section(gs_report):
    md = gs_report.render("markdown")
    assert "#### Diagnostics" in md and "`LCD_BOTTLENECK`" in md


# -- cache key + serving envelope ---------------------------------------------


def test_cache_key_separates_diagnose():
    kernel = parse_aarch64(GS_TX2_ASM, name="gs")
    model = thunderx2()
    plain = _cache_key(kernel, model, 4, ("tp",))
    diag = _cache_key(kernel, model, 4, ("tp",), diagnose=True)
    assert plain != diag
    assert plain[:4] == diag[:4]


def test_request_key_and_dict_carry_diagnose():
    a = AnalysisRequest(asm="fadd d0, d0, d1", arch="tx2")
    b = AnalysisRequest(asm="fadd d0, d0, d1", arch="tx2", diagnose=True)
    assert a.key != b.key
    assert b.key[-1] is True
    # Wire round-trip, and v1 payloads (no diagnose field) default to False.
    assert AnalysisRequest.from_dict(b.to_dict()).diagnose is True
    legacy = {k: v for k, v in a.to_dict().items() if k != "diagnose"}
    assert AnalysisRequest.from_dict(legacy).diagnose is False


def test_service_passes_findings_through_envelope():
    service = AnalysisService()
    req = AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", unroll=4,
                          name="gs-diag", diagnose=True)
    (resp,) = service.submit_batch([req])
    assert resp.ok
    assert resp.report.findings
    codes = {f.code for f in resp.report.findings}
    assert "LCD_BOTTLENECK" in codes
    wire = json.loads(json.dumps(resp.to_dict()))
    assert wire["report"]["findings"]
    # The plain request must not be served from the diagnose cache line.
    (plain,) = service.submit_batch([
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", unroll=4, name="gs-diag")])
    assert plain.report.findings is None


def test_api_diagnose_rejected_for_hlo():
    with pytest.raises(ValueError, match="asm targets only"):
        analyze("HloModule m\nENTRY e { ROOT c = f32[] constant(0) }",
                arch="tpu-v5e", diagnose=True)


def test_diagnose_on_degraded_rungs():
    kernel = parse_aarch64(GS_TX2_ASM, name="gs")
    model = thunderx2()
    tp_only = analyze_kernel_rung(kernel, model, 4, rung="tp_only",
                                  diagnose=True)
    assert tp_only.findings is not None
    # No LCD/CP stages → no chain or unroll findings, but port data exists.
    codes = {f.code for f in tp_only.findings}
    assert "LCD_BOTTLENECK" not in codes and "UNROLL_ADVICE" not in codes
    assert "PORT_HOTSPOT" in codes
    # diagnose() is also callable standalone on a finished analysis.
    full = analyze_kernel(kernel, model, 4)
    assert full.findings is None
    assert diagnose(full) == analyze_kernel(kernel, model, 4,
                                            diagnose=True).findings
