"""HLO analyzer tests: parser, roofline terms, collective accounting,
critical path, and while-loop LCD — on real compiled modules (8 host-device
SPMD in a subprocess-safe way: these tests run under the default 1-device
runtime and use handwritten HLO text plus small jit'd modules)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo import (
    TPU_V5E, hlo_critical_path, hlo_loop_carried, parse_hlo,
    roofline_report,
)
from repro.core.hlo.costs import HLOCostModel
from repro.core.hlo.roofline import collective_stats

SIMPLE_HLO = """
HloModule test_module, num_partitions=4

%add_red (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %y = f32[8,128]{1,0} multiply(%x, %x)
  %z = f32[8,128]{1,0} all-reduce(%y), channel_id=1, replica_groups={{0,1,2,3}}, to_apply=%add_red
  ROOT %t = (s32[], f32[8,128]) tuple(%i2, %z)
}

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (arg: f32[8,128], w: f32[128,256]) -> f32[8,256] {
  %arg = f32[8,128]{1,0} parameter(0)
  %w = f32[128,256]{1,0} parameter(1)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%zero, %arg)
  %loop = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body
  %out = f32[8,128]{1,0} get-tuple-element(%loop), index=1
  ROOT %dot = f32[8,256]{1,0} dot(%out, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_parse_structure():
    mod = parse_hlo(SIMPLE_HLO)
    assert mod.num_partitions == 4
    assert mod.entry_name == "main"
    assert len(mod.computations) == 4
    dot = mod.entry.op_by_name("dot")
    assert dot.opcode == "dot" and dot.is_root
    assert dot.shapes[0].dims == (8, 256)
    assert dot.shapes[0].bytes == 8 * 256 * 4


def test_dot_flops():
    mod = parse_hlo(SIMPLE_HLO)
    cm = HLOCostModel(mod, TPU_V5E)
    dot = mod.entry.op_by_name("dot")
    assert cm.op_flops(dot, mod.entry) == 2 * 8 * 256 * 128


def test_while_trip_count_from_compare():
    mod = parse_hlo(SIMPLE_HLO)
    cm = HLOCostModel(mod, TPU_V5E)
    loop = mod.entry.op_by_name("loop")
    assert cm.while_trip_count(loop) == 10


def test_collectives_scaled_by_trip_count():
    mod = parse_hlo(SIMPLE_HLO)
    cm = HLOCostModel(mod, TPU_V5E)
    stats = collective_stats(mod, TPU_V5E, exec_counts=cm.execution_counts())
    assert stats.counts["all-reduce"] == 10
    assert stats.total_bytes == pytest.approx(10 * 8 * 128 * 4)


def test_lcd_finds_loop_carried_chain():
    res = hlo_loop_carried(SIMPLE_HLO)
    assert res.chains
    longest = res.longest
    assert longest.trip_count == 10
    # The f32 state (index 1) chain should dominate the counter chain.
    assert longest.tuple_index == 1
    assert any("all-reduce" in op or op == "z" for op in longest.ops)


def test_critical_path_spans_loop_and_dot():
    cp = hlo_critical_path(SIMPLE_HLO)
    opcodes = [n.opcode for n in cp.path]
    assert "while" in opcodes and "dot" in opcodes
    assert cp.seconds > 0


def test_roofline_report_from_text():
    rep = roofline_report(SIMPLE_HLO, name="unit",
                          model_flops=2 * 8 * 256 * 128 * 4)
    assert rep.num_partitions == 4
    assert set(rep.terms) == {"MXU", "HBM", "ICI"}
    assert rep.collective.total_bytes > 0
    assert rep.dominant in ("MXU", "HBM", "ICI")
    assert "bound" in rep.render() or rep.render()


def test_roofline_on_compiled_module():
    """End-to-end on a real compiled artifact (1 device)."""
    from repro.core.hlo import roofline_from_compiled

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=6)
        return y.sum()

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    rep = roofline_from_compiled(compiled, name="t",
                                 model_flops=2 * 64 * 64 * 64 * 6)
    # Trip-aware correction must recover the 6x of the scan.
    assert rep.useful_ratio is not None
    assert 0.3 < rep.useful_ratio < 1.5
    lcd = hlo_loop_carried(compiled)
    assert lcd.chains and lcd.longest.trip_count == 6


def test_known_trip_count_backend_config():
    hlo = SIMPLE_HLO.replace(
        "while(%init), condition=%cond, body=%body",
        'while(%init), condition=%cond, body=%body, '
        'backend_config={"known_trip_count":{"n":"7"}}')
    mod = parse_hlo(hlo)
    cm = HLOCostModel(mod, TPU_V5E)
    loop = mod.entry.op_by_name("loop")
    assert cm.while_trip_count(loop) == 7


def test_tuple_type_with_index_comments():
    """HLO inserts /*index=N*/ comments in wide tuple types."""
    line = ("  %w = (s32[], f32[4,4]{1,0}, /*index=2*/f32[8]) "
            "while(%t), condition=%c, body=%b")
    mod = parse_hlo("ENTRY %e (p: s32[]) -> s32[] {\n" + line + "\n}")
    op = mod.entry.op_by_name("w")
    assert op is not None and op.opcode == "while"
    assert len(op.shapes) == 3
