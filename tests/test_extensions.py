"""Beyond-paper analyzer extensions (the paper's §IV-B future-work list):
hidden flag dependencies, load-after-store forwarding, and the Zen 2 /
Neoverse N1 machine models."""

import pytest

from repro.core import analyze_kernel, parse_aarch64, parse_x86
from repro.core.analysis import build_dag, critical_path
from repro.core.analysis.dag import DependencyDAG
from repro.core.machine import neoverse_n1, zen2
from repro.core.validation import GS_CLX_ASM, GS_TX2_ASM


def x86_kernel(body):
    return parse_x86(f"# OSACA-BEGIN\n{body}\n# OSACA-END")


def a64_kernel(body):
    return parse_aarch64(f"# OSACA-BEGIN\n{body}\n# OSACA-END")


# -- hidden flag dependencies ---------------------------------------------------


def test_flags_edge_cmp_to_jcc():
    k = x86_kernel("""
cmpq %r13, %rax
jne .L1
""")
    m = zen2()
    plain = build_dag(k, m)
    assert all(not s for s in plain.succs[:1])  # no edge without flags
    flagged = build_dag(k, m, model_flags=True)
    assert 1 in flagged.succs[0]  # cmp -> jne via %flags


def test_flags_not_crossing_writer():
    """A later flag writer supersedes the earlier one (WAW on %flags)."""
    k = x86_kernel("""
cmpq %r13, %rax
addq $1, %rbx
jne .L1
""")
    flagged = build_dag(k, zen2(), model_flags=True)
    # jne (node 2) depends on addq (node 1, latest flag writer), not cmp.
    assert 2 in flagged.succs[1]
    assert 2 not in flagged.succs[0]


def test_flags_aarch64_subs_to_branch():
    k = a64_kernel("""
subs x1, x1, 1
bne .L1
""")
    flagged = build_dag(k, neoverse_n1(), model_flags=True)
    assert 1 in flagged.succs[0]


# -- load-after-store forwarding -------------------------------------------------


def test_store_forward_same_address():
    k = x86_kernel("""
vaddsd %xmm1, %xmm2, %xmm0
movsd %xmm0, 8(%rax)
movsd 8(%rax), %xmm3
vaddsd %xmm3, %xmm3, %xmm4
""")
    m = zen2()
    plain = critical_path(k, m)
    # Without forwarding the load is independent: CP = add + store.
    fwd_dag = build_dag(k, m, model_store_forwarding=True)
    store_node = next(n.nid for n in fwd_dag.nodes
                      if n.cost.form.mnemonic == "movsd" and n.cost.form.stores)
    load_node = next(n.nid for n in fwd_dag.nodes
                     if n.cost.form.mnemonic == "movsd" and n.cost.form.loads)
    assert load_node in fwd_dag.succs[store_node]
    # And the CP grows: add(3) -> store(4) -> load(7) -> add(3).
    dist, parent = fwd_dag.longest_paths()
    assert max(dist) == pytest.approx(17.0)
    assert max(dist) > plain.length


def test_store_forward_different_address_no_edge():
    k = x86_kernel("""
movsd %xmm0, 8(%rax)
movsd 16(%rax), %xmm3
""")
    dag = build_dag(k, zen2(), model_store_forwarding=True)
    assert dag.succs[0] == []


# -- new machine models -----------------------------------------------------------


def test_zen2_gauss_seidel_faster_than_zen1():
    """Zen 2's 3-cycle FMUL shortens the Gauss-Seidel LCD vs Zen 1."""
    from repro.core.machine import zen

    k = parse_x86(GS_CLX_ASM, name="gs")
    a1 = analyze_kernel(k, zen(), unroll=4)
    a2 = analyze_kernel(k, zen2(), unroll=4)
    assert a2.lcd_per_it < a1.lcd_per_it  # 3+3+3 vs 3+3+4 per iteration
    assert a2.lcd_per_it == pytest.approx((12 + 9 + 12 + 9) / 4)
    assert a2.tp_per_it <= a1.tp_per_it  # 3 AGUs vs 2
    assert a2.tp_per_it <= a2.lcd_per_it <= a2.cp_per_it


def test_n1_gauss_seidel_bracket():
    """Neoverse N1 analysis of the TX2 kernel: 2-cycle FADD shrinks the LCD."""
    k = parse_aarch64(GS_TX2_ASM, name="gs")
    a = analyze_kernel(k, neoverse_n1(), unroll=4)
    # chain per iteration = fadd(2) + fadd(2) + fmul(3) = 7.
    assert a.lcd_per_it == pytest.approx(7.0)
    assert a.tp_per_it <= a.lcd_per_it <= a.cp_per_it
    assert a.tp.bottleneck_port in ("V0", "V1", "L0", "L1")


def test_flags_dont_change_table1():
    """With flags ON, the Gauss-Seidel LCD/CP are unchanged (the FP chain
    dominates the 1-cycle flag chain) — the paper's numbers are robust."""
    from repro.core.analysis.lcd import loop_carried_dependencies
    from repro.core.machine import cascade_lake

    k = parse_x86(GS_CLX_ASM)
    base = loop_carried_dependencies(k, cascade_lake())
    assert base.longest == pytest.approx(56.0)
