"""Public-API surface tests: the ``repro.api`` facade, the architecture
registry, the serializable ``AnalysisReport`` (JSON round-trip), the versioned
``AnalysisService`` request/response envelopes, and the serve CLI's JSON-lines
output."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.api import analyze, analyze_raw, asm_arch_ids, get_arch, list_arch_ids
from repro.core import analyze_kernel, analyze_kernels
from repro.core.analysis import AnalysisReport, clear_analysis_cache
from repro.core.isa import parse_aarch64
from repro.core.machine import thunderx2
from repro.core.registry import ArchSpec, register_arch
from repro.core.validation import GS_CLX_ASM, GS_TX2_ASM, GS_ZEN_ASM, TABLE1
from repro.serving.analysis import (API_VERSION, AnalysisRequest,
                                    AnalysisResponse, AnalysisService)

GS_CASES = [("tx2", GS_TX2_ASM), ("csx", GS_CLX_ASM), ("zen", GS_ZEN_ASM)]

WHILE_HLO = """
HloModule api_test, num_partitions=1

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %y = f32[8,128]{1,0} multiply(%x, %x)
  ROOT %t = (s32[], f32[8,128]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(8)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%zero, %a)
  %w = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%w), index=1
}
"""


# -- facade: one call, many source shapes -------------------------------------


@pytest.mark.parametrize("arch,asm", GS_CASES)
def test_facade_matches_legacy_numbers_and_text(arch, asm):
    """analyze() == the legacy parser+model+analyze_kernel pipeline, for the
    paper's validation kernels — numbers and rendered report."""
    spec = get_arch(arch)
    legacy = analyze_kernel(spec.parser(asm, name="gauss-seidel"),
                            spec.model_factory(), unroll=4)
    report = analyze(asm, arch=arch, unroll=4, name="gauss-seidel")
    assert report.prediction_bracket() == legacy.prediction_bracket()
    assert round(report.tp_per_it, 2) == TABLE1[arch].tp
    assert report.lcd_per_it == pytest.approx(TABLE1[arch].lcd)
    assert report.cp_per_it == pytest.approx(TABLE1[arch].cp)
    assert report.render("text") == legacy.report()


def test_facade_accepts_file_path(tmp_path):
    path = tmp_path / "gs.s"
    path.write_text(GS_TX2_ASM)
    from_text = analyze(GS_TX2_ASM, arch="tx2", unroll=4)
    from_path = analyze(str(path), arch="thunderx2", unroll=4)  # alias too
    assert from_path.prediction_bracket() == from_text.prediction_bracket()
    assert from_path.kernel_name == "gs.s"


def test_facade_accepts_parsed_kernel():
    kernel = parse_aarch64(GS_TX2_ASM, name="pre-parsed")
    report = analyze(kernel, arch="tx2", unroll=4)
    assert report.kernel_name == "pre-parsed"
    assert report.prediction_bracket() == \
        analyze(GS_TX2_ASM, arch="tx2", unroll=4).prediction_bracket()


def test_facade_accepts_hlo_module_same_call():
    """An HLO while-body answers with the same bracket shape as an asm loop."""
    from repro.core.hlo import parse_hlo

    asm_report = analyze(GS_TX2_ASM, arch="tx2", unroll=4)
    hlo_report = analyze(parse_hlo(WHILE_HLO), arch="tpu-v5e")
    text_report = analyze(WHILE_HLO)  # auto-detected, default arch
    assert set(hlo_report.prediction_bracket()) == \
        set(asm_report.prediction_bracket())
    assert hlo_report.kind == "hlo" and text_report.kind == "hlo"
    assert hlo_report.lcd_block > 0  # the x*x while chain is carried
    assert hlo_report.cp_block >= hlo_report.lcd_block - 1e-12


def test_facade_accepts_hlo_file_path(tmp_path):
    path = tmp_path / "module.hlo.txt"
    path.write_text(WHILE_HLO)
    from_path = analyze(str(path), arch="tpu-v5e")
    from_text = analyze(WHILE_HLO, arch="tpu-v5e")
    assert from_path.kind == "hlo"
    assert from_path.prediction_bracket() == from_text.prediction_bracket()
    # An HLO *file* auto-routes even under the default asm arch.
    assert analyze(str(path)).kind == "hlo"
    with pytest.raises(ValueError, match="expects an HLO module"):
        analyze("fadd d0, d0, d1", arch="tpu-v5e")
    with pytest.raises(ValueError, match="expects an HLO module"):
        analyze(parse_aarch64("fadd d0, d0, d1"), arch="tpu")
    with pytest.raises(ValueError, match="not a valid HLO module"):
        analyze("HloModule truncated\n", arch="tx2")  # auto-routed garbage


def test_facade_rejects_unanalyzable_source():
    with pytest.raises(TypeError):
        analyze(12345, arch="tx2")
    with pytest.raises(FileNotFoundError):
        analyze("no/such/kernel.s", arch="tx2")


# -- JSON round-trip ----------------------------------------------------------


@pytest.mark.parametrize("arch,asm", GS_CASES)
def test_report_json_roundtrip_bit_identical(arch, asm):
    report = analyze(asm, arch=arch, unroll=4, name="gauss-seidel")
    payload = json.dumps(report.to_dict(), sort_keys=True)
    restored = AnalysisReport.from_dict(json.loads(payload))
    assert json.dumps(restored.to_dict(), sort_keys=True) == payload
    assert restored.render("text") == report.render("text")
    assert restored.prediction_bracket() == report.prediction_bracket()


def test_hlo_report_json_roundtrip():
    report = analyze(WHILE_HLO, arch="tpu")
    payload = json.dumps(report.to_dict(), sort_keys=True)
    restored = AnalysisReport.from_dict(json.loads(payload))
    assert json.dumps(restored.to_dict(), sort_keys=True) == payload
    assert restored.render("text") == report.render("text")


def test_report_rejects_newer_schema():
    report = analyze("fadd d0, d0, d1", arch="tx2")
    data = report.to_dict()
    data["schema_version"] = 99
    with pytest.raises(ValueError):
        AnalysisReport.from_dict(data)


def test_renderers_pluggable():
    report = analyze(GS_TX2_ASM, arch="tx2", unroll=4)
    assert json.loads(report.render("json"))["arch"] == "tx2"
    md = report.render("markdown")
    assert md.startswith("###") and "`tx2`" in md
    with pytest.raises(ValueError):
        report.render("nope")


# -- registry -----------------------------------------------------------------


def test_registry_alias_resolution():
    assert get_arch("cascadelake").id == "csx"
    assert get_arch("CLX").id == "csx"
    assert get_arch("cascade-lake").id == "csx"
    assert get_arch("thunderx2").id == "tx2"
    assert get_arch("graviton2").id == "n1"
    assert get_arch(" Zen2 ").id == "zen2"
    assert get_arch("tpu").is_hlo


def test_registry_contents():
    ids = list_arch_ids()
    assert {"tx2", "csx", "zen", "zen2", "n1", "tpu-v5e"} <= set(ids)
    assert "tpu-v5e" not in asm_arch_ids()
    for arch_id in asm_arch_ids():
        spec = get_arch(arch_id)
        assert spec.parser is not None and spec.frequency_ghz > 0
        model = spec.model_factory()
        # The registry card must agree with the machine model it names.
        assert spec.frequency_ghz == model.frequency_ghz
        assert spec.isa == model.isa and spec.id == model.name


def test_registry_unknown_arch_lists_known():
    with pytest.raises(ValueError, match="unknown arch 'skylake'"):
        get_arch("skylake")


def test_registry_rejects_conflicting_alias_atomically():
    with pytest.raises(ValueError, match="already registered"):
        register_arch(ArchSpec(id="imposter", isa="x86", aliases=("csx",),
                               model_factory=lambda: None, frequency_ghz=1.0))
    # The failed registration must leave no trace (no half-registered names).
    with pytest.raises(ValueError, match="unknown arch"):
        get_arch("imposter")
    assert get_arch("csx").id == "csx"


# -- versioned service --------------------------------------------------------


def test_service_batch_isolates_malformed_request():
    """One bad request yields an error response; the rest of the wave is
    analyzed normally."""
    service = AnalysisService()
    responses = service.submit_batch([
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", unroll=4, name="good-1"),
        AnalysisRequest(asm=GS_CLX_ASM, arch="not-a-machine", name="bad"),
        AnalysisRequest(asm=GS_CLX_ASM, arch="csx", isa="martian", name="bad-isa"),
        AnalysisRequest(asm=GS_CLX_ASM, arch="cascadelake", unroll=4,
                        name="good-2"),
    ])
    assert [r.ok for r in responses] == [True, False, False, True]
    assert all(r.version == API_VERSION for r in responses)
    assert "unknown arch" in responses[1].error
    assert "unknown isa" in responses[2].error
    # unroll=0 (reachable from wire data) must be a per-request error, not a
    # deferred ZeroDivisionError during report serialization.
    (bad_unroll,) = service.submit_batch([
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", unroll=0)])
    assert not bad_unroll.ok and "unroll" in bad_unroll.error
    with pytest.raises(ValueError, match="unroll"):
        analyze(GS_TX2_ASM, arch="tx2", unroll=0)
    assert responses[0].report.prediction_bracket()["expected_lcd"] == \
        pytest.approx(TABLE1["tx2"].lcd)
    assert responses[3].report.arch == "csx"
    # Envelopes survive the wire.
    wire = json.dumps([r.to_dict() for r in responses])
    restored = [AnalysisResponse.from_dict(d) for d in json.loads(wire)]
    assert [r.ok for r in restored] == [True, False, False, True]
    assert restored[0].report.render("text") == \
        responses[0].report.render("text")


def test_service_negatively_caches_parse_failures(monkeypatch):
    """A hot malformed kernel is parsed once; retries are served from the
    cache as error responses instead of re-parsing every wave."""
    import repro.serving.analysis as serving_analysis

    calls = {"n": 0}

    def exploding_parser(text, name="kernel"):
        calls["n"] += 1
        raise RuntimeError("parse exploded")

    monkeypatch.setitem(serving_analysis._PARSERS, "aarch64",
                        exploding_parser)
    service = AnalysisService()
    bad = AnalysisRequest(asm="whatever", arch="tx2", name="bad")
    r1 = service.submit(bad)
    r2 = service.submit(bad)
    assert not r1.ok and not r2.ok
    assert "parse exploded" in r1.error and r1.error == r2.error
    assert calls["n"] == 1
    # HLO targets are rejected with a pointer to the facade.
    hlo = service.submit(AnalysisRequest(asm=GS_TX2_ASM, arch="tpu-v5e"))
    assert not hlo.ok and "HLO target" in hlo.error


def test_service_shares_facade_model_cache():
    from repro.api import model_for

    service = AnalysisService()
    assert service.model_for("cascadelake") is model_for("csx")


def test_request_key_canonical_across_aliases():
    a = AnalysisRequest(asm="fadd d0, d0, d1", arch="csx")
    b = AnalysisRequest(asm="fadd d0, d0, d1", arch="cascadelake", isa="x86")
    assert a.key == b.key
    unknown = AnalysisRequest(asm="x", arch="not-a-machine")
    assert unknown.key == ("not-a-machine", "", "x", 1,
                           ("tp", "cp", "lcd", "sim"), False)
    # predictors are part of the identity: a sim-less request must not
    # collide with (or be served from) a full analysis.
    subset = AnalysisRequest(asm="fadd d0, d0, d1", arch="csx",
                             predictors=("tp", "cp", "lcd"))
    assert subset.key != a.key


def test_service_legacy_analyze_batch_still_raises():
    service = AnalysisService()
    with pytest.raises(ValueError, match="unknown arch"):
        service.analyze_batch([AnalysisRequest(asm="fadd d0, d0, d1",
                                               arch="not-a-machine")])
    # and still returns live Analysis objects for good requests
    analysis = service.analyze(AnalysisRequest(asm=GS_TX2_ASM, arch="tx2",
                                               unroll=4))
    assert analysis.lcd_per_it == pytest.approx(TABLE1["tx2"].lcd)


def test_service_cache_hit_carries_requester_name():
    """Regression: a cache hit used to return the first requester's Analysis
    including its kernel.name."""
    service = AnalysisService()
    first = service.analyze(AnalysisRequest(asm=GS_TX2_ASM, arch="tx2",
                                            unroll=4, name="first"))
    second = service.analyze(AnalysisRequest(asm=GS_TX2_ASM, arch="tx2",
                                             unroll=4, name="second"))
    assert service.stats["hits"] >= 1
    assert first.kernel.name == "first"
    assert second.kernel.name == "second"
    assert second.tp is first.tp  # shared result objects, renamed view
    # Same for in-wave duplicates and the versioned envelope path.
    r1, r2 = service.submit_batch([
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", unroll=4, name="wave-a"),
        AnalysisRequest(asm=GS_TX2_ASM, arch="thunderx2", unroll=4,
                        name="wave-b"),  # alias: same canonical cache key
    ])
    assert r1.report.kernel_name == "wave-a"
    assert r2.report.kernel_name == "wave-b"
    # Cross-wave cache hits reuse the memoized report snapshot: only the
    # kernel_name is re-stamped, the rows tuple is shared.
    (r3,) = service.submit_batch([
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", unroll=4, name="wave-c")])
    assert r3.report.kernel_name == "wave-c"
    assert r3.report.rows is r1.report.rows


def test_analyze_kernels_cache_key_covers_memory_structure():
    """Regression: programmatically built forms (raw='') differing only in
    load/store writeback structure must not collide in the process LRU."""
    from repro.core.isa import InstructionForm, Kernel, MemoryRef, Register

    def str_kernel(post_index):
        form = InstructionForm(
            mnemonic="str",
            source_registers=("d0", "x1"),
            dest_registers=("x1",) if post_index else (),
            stores=(MemoryRef(base=Register("x1"), post_index=post_index),),
        )
        return Kernel(instructions=(form,), isa="aarch64", name="k")

    from repro.core.analysis.analyze import _cache_key

    clear_analysis_cache()
    model = thunderx2()
    assert _cache_key(str_kernel(False), model, 1) != \
        _cache_key(str_kernel(True), model, 1)
    plain = analyze_kernels([str_kernel(False)], model)[0]
    writeback = analyze_kernels([str_kernel(True)], model)[0]
    # A collision would serve the first analysis as a shared view (same tp
    # object); distinct kernels must get distinct analyses.
    assert writeback.tp is not plain.tp
    clear_analysis_cache()


def test_analyze_kernels_cache_hit_carries_requester_name():
    """Same regression at the batch-API level (process LRU)."""
    clear_analysis_cache()
    model = thunderx2()
    k1 = parse_aarch64(GS_TX2_ASM, name="alpha")
    k2 = parse_aarch64(GS_TX2_ASM, name="beta")
    a1 = analyze_kernels([k1], model, unroll=4)[0]
    a2 = analyze_kernels([k2], model, unroll=4)[0]
    assert a1.kernel.name == "alpha"
    assert a2.kernel.name == "beta"
    assert a2.lcd is a1.lcd  # cached result shared under the view
    assert a2.report() != a1.report()  # header carries the right name
    clear_analysis_cache()


# -- serve CLI JSON lines -----------------------------------------------------


def test_serve_analyze_emits_parseable_json_lines():
    repo_root = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-W", "ignore", "-m", "repro.launch.serve",
         "--mode", "analyze", "--requests", "5", "--arch", "zen2"],
        capture_output=True, text=True, timeout=120,
        cwd=repo_root, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr
    lines = [json.loads(l) for l in proc.stdout.splitlines() if l.strip()]
    assert len(lines) == 6  # 5 responses + summary
    assert all(o["ok"] and o["version"] == API_VERSION for o in lines[:-1])
    assert all(o["report"]["arch"] == "zen2" for o in lines[:-1])
    assert lines[-1]["event"] == "summary" and lines[-1]["errors"] == 0
