"""Fault-tolerance substrate tests: checkpoint atomicity/roundtrip, async
writer, data-pipeline determinism, heartbeats, stragglers, supervised
restart, elastic re-mesh planning."""

import json
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer, latest_checkpoint, restore_checkpoint, save_checkpoint,
)
from repro.configs import RunConfig, get_config, tiny_variant
from repro.data import make_batch
from repro.launch.elastic import plan_resize
from repro.launch.ft import HeartbeatRegistry, StragglerDetector, Supervisor


def small_tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"w": jnp.ones((2, 2), jnp.bfloat16), "n": jnp.asarray(3, jnp.int32)},
        "scalar": jnp.asarray(1.5, jnp.float32),
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = small_tree()
    save_checkpoint(tmp_path, 7, tree)
    restored, step = restore_checkpoint(latest_checkpoint(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype and a.shape == b.shape


def test_checkpoint_atomic_no_partial(tmp_path):
    save_checkpoint(tmp_path, 1, small_tree())
    # A stale tmp dir (simulated crash) must not be picked up.
    (tmp_path / "tmp.2").mkdir()
    (tmp_path / "tmp.2" / "junk.bin").write_bytes(b"xx")
    latest = latest_checkpoint(tmp_path)
    assert latest is not None and latest.name == "step_00000001"


def test_checkpoint_pruning(tmp_path):
    for s in range(5):
        save_checkpoint(tmp_path, s, small_tree(), keep=2)
    names = sorted(p.name for p in tmp_path.glob("step_*"))
    assert names == ["step_00000003", "step_00000004"]


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(latest_checkpoint(tmp_path), {"a": jnp.ones((3, 3))})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    ck.save(5, small_tree())
    ck.wait()
    restored, step = restore_checkpoint(latest_checkpoint(tmp_path), small_tree())
    assert step == 5


def test_data_determinism_and_restart_safety():
    cfg = tiny_variant(get_config("tinyllama-1.1b"))
    a = make_batch(cfg, 4, 32, seed=0, step=10)
    b = make_batch(cfg, 4, 32, seed=0, step=10)
    c = make_batch(cfg, 4, 32, seed=0, step=11)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are the next-token shift
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_heartbeat_registry():
    hb = HeartbeatRegistry(timeout_s=10.0)
    hb.beat("h0", now=100.0)
    hb.beat("h1", now=100.0)
    assert hb.dead_hosts(now=105.0) == []
    assert hb.dead_hosts(now=111.0) == ["h0", "h1"]
    hb.beat("h0", now=112.0)
    assert hb.dead_hosts(now=115.0) == ["h1"]


def test_straggler_detection():
    det = StragglerDetector(z_threshold=4.0)
    for step in range(8):
        for h in range(6):
            det.record(f"h{h}", 1.0 + 0.01 * h)
    det.record("h5", 3.0)  # one host suddenly 3x slower
    assert det.stragglers() == ["h5"]


def test_supervisor_restarts_from_checkpoint(tmp_path):
    saved = {}

    def save_fn(step, state):
        saved["state"], saved["step"] = state, step

    def restore_fn():
        return saved["state"], saved["step"]

    crashes = {"left": 2}

    def step_fn(state, step):
        if step == 7 and crashes["left"] > 0:
            crashes["left"] -= 1
            raise RuntimeError("simulated node failure")
        return state + 1

    sup = Supervisor(step_fn, save_fn, restore_fn, checkpoint_every=5,
                     max_restarts=3)
    final, step = sup.run(0, 0, 10)
    assert step == 10
    assert sup.restarts == 2
    # Steps 5..7 were re-executed after each crash: total increments > 10.
    assert final >= 10


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    def step_fn(state, step):
        raise RuntimeError("persistent failure")

    sup = Supervisor(step_fn, lambda s, st: None, lambda: (0, 0),
                     checkpoint_every=5, max_restarts=2)
    with pytest.raises(RuntimeError):
        sup.run(0, 0, 5)


def test_elastic_plan_shrink_and_grow():
    p = plan_resize(8, 4, old_global_batch=64, old_lr=1e-3)
    assert p.n_devices in (4,)
    assert p.global_batch == 32  # per-device batch preserved
    assert p.learning_rate == pytest.approx(5e-4)
    p2 = plan_resize(4, 8, old_global_batch=32, old_lr=5e-4)
    assert p2.global_batch == 64
    assert p2.learning_rate == pytest.approx(1e-3)


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint saved un-sharded restores under a different mesh context
    (reshard-on-load)."""
    from repro.launch.elastic import apply_resize
    from repro.train import init_train_state

    cfg = tiny_variant(get_config("tinyllama-1.1b"))
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 3, state)

    run = RunConfig(zero=False, fsdp=False)
    plan = plan_resize(1, 1, old_global_batch=8, old_lr=1e-3)
    restored, step = apply_resize(plan, cfg, run, tmp_path)
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(restored.params["embed"], np.float32),
        np.asarray(state.params["embed"], np.float32))


# ---------------------------------------------------------------------------
# Heartbeat registry with an injected clock (no sleeps, no wall clock).
# ---------------------------------------------------------------------------

def test_heartbeat_injected_clock_boundary():
    """A beat exactly ``timeout_s`` old is still alive; strictly older dies."""
    t = {"now": 100.0}
    reg = HeartbeatRegistry(timeout_s=10.0, clock=lambda: t["now"])
    reg.beat("h0")
    reg.beat("h1")
    assert reg.dead_hosts() == []
    t["now"] = 110.0  # exactly timeout_s since the beats
    assert reg.dead_hosts() == []
    assert reg.alive_count() == 2
    t["now"] = 110.0 + 1e-6  # strictly past the boundary
    assert sorted(reg.dead_hosts()) == ["h0", "h1"]
    assert reg.alive_count() == 0


def test_heartbeat_late_beat_revives_host():
    """A host flagged dead comes back alive on its next beat (late
    heartbeat revival), while silent peers stay dead."""
    t = {"now": 0.0}
    reg = HeartbeatRegistry(timeout_s=5.0, clock=lambda: t["now"])
    reg.beat("h0")
    reg.beat("h1")
    t["now"] = 20.0
    assert sorted(reg.dead_hosts()) == ["h0", "h1"]
    reg.beat("h0")  # late beat at the injected now
    assert reg.dead_hosts() == ["h1"]
    assert reg.alive_count() == 1
    # Explicit now= override still works alongside the injected clock.
    reg.beat("h1", now=19.0)
    assert reg.dead_hosts(now=24.0) == []
    assert reg.dead_hosts(now=24.0 + 1e-6) == ["h1"]


# ---------------------------------------------------------------------------
# Data pipeline: producer failures surface at the consumer; shutdown is
# bounded.
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return tiny_variant(get_config("tinyllama-1.1b"))


def test_pipeline_producer_exception_propagates():
    """An exception on the prefetch thread reaches the consumer as a
    RuntimeError with the original as ``__cause__`` — not a silent hang."""
    from repro.data import DataPipeline

    class FailingPipeline(DataPipeline):
        def _produce_one(self, step):
            if step >= 2:
                raise ValueError(f"corrupt shard at step {step}")
            return super()._produce_one(step)

    pipe = FailingPipeline(_tiny_cfg(), batch=2, seq=16, seed=0)
    assert next(pipe)["tokens"].shape == (2, 16)
    assert next(pipe)["tokens"].shape == (2, 16)
    with pytest.raises(RuntimeError, match="producer failed.*corrupt shard"):
        # The failure lands either as the queued sentinel or (if the thread
        # already exited) the dead-thread probe; both carry the cause.
        next(pipe)
    pipe.close()


def test_pipeline_immediate_failure_does_not_hang():
    from repro.data import DataPipeline

    class DeadOnArrival(DataPipeline):
        def _produce_one(self, step):
            raise KeyError("missing field")

    pipe = DeadOnArrival(_tiny_cfg(), batch=2, seq=16, seed=0)
    with pytest.raises(RuntimeError) as ei:
        next(pipe)
    assert isinstance(ei.value.__cause__, KeyError)
    pipe.close()


def test_pipeline_close_surfaces_stuck_thread():
    """close() raises when the producer cannot stop (wedged outside the
    queue), instead of silently leaking the thread."""
    from repro.data import DataPipeline

    release = threading.Event()

    class StuckPipeline(DataPipeline):
        def _producer(self):
            release.wait()  # ignores _stop: simulates a wedged device_put

    pipe = StuckPipeline(_tiny_cfg(), batch=2, seq=16, seed=0)
    try:
        with pytest.raises(RuntimeError, match="failed to stop"):
            pipe.close(timeout=0.1)
    finally:
        release.set()  # let the thread exit so the test process stays clean
        pipe._thread.join(timeout=2.0)
