"""Paper validation: Table I (Gauss-Seidel on TX2/CLX/ZEN) and Table II
structure for TX2.  These are the faithful-reproduction gates."""

import pytest

from repro.core import analyze_kernel, cascade_lake, parse_aarch64, parse_x86, thunderx2, zen
from repro.core.validation import GS_CLX_ASM, GS_TX2_ASM, GS_ZEN_ASM, TABLE1

CASES = [
    ("tx2", GS_TX2_ASM, parse_aarch64, thunderx2),
    ("csx", GS_CLX_ASM, parse_x86, cascade_lake),
    ("zen", GS_ZEN_ASM, parse_x86, zen),
]


@pytest.fixture(scope="module")
def analyses():
    out = {}
    for arch, asm, parse, model in CASES:
        out[arch] = analyze_kernel(parse(asm, name="gauss-seidel"), model(),
                                   unroll=4)
    return out


@pytest.mark.parametrize("arch", [c[0] for c in CASES])
def test_throughput_matches_paper(analyses, arch):
    assert round(analyses[arch].tp_per_it, 2) == TABLE1[arch].tp


@pytest.mark.parametrize("arch", [c[0] for c in CASES])
def test_lcd_matches_paper(analyses, arch):
    assert analyses[arch].lcd_per_it == pytest.approx(TABLE1[arch].lcd)


@pytest.mark.parametrize("arch", [c[0] for c in CASES])
def test_cp_matches_paper(analyses, arch):
    assert analyses[arch].cp_per_it == pytest.approx(TABLE1[arch].cp)


@pytest.mark.parametrize("arch", [c[0] for c in CASES])
def test_bracket_contains_measurement(analyses, arch):
    """The paper's headline claim: measured cy/it lies in [TP, CP] and close
    to the LCD."""
    a = analyses[arch]
    measured = TABLE1[arch].measured_cy_per_it
    assert a.tp_per_it <= measured <= a.cp_per_it
    assert abs(measured - a.lcd_per_it) / measured < 0.05


def test_tx2_port_pressure_matches_table2(analyses):
    """Bottom row of Table II: per-iteration port pressures."""
    tp = analyses["tx2"].tp
    per_it = {p: v / 4 for p, v in tp.port_pressure.items()}
    assert round(per_it["P0"], 2) == 2.46
    assert round(per_it["P1"], 2) == 2.46
    assert round(per_it["P2"], 2) == 0.33
    assert per_it["P3"] == pytest.approx(2.0)
    assert per_it["P4"] == pytest.approx(2.0)
    assert per_it["P5"] == pytest.approx(1.0)


def test_tx2_lcd_chain_is_fp_chain(analyses):
    """Table II LCD column: exactly the 12 fadd/fmul ops carry the cycle."""
    a = analyses["tx2"]
    kernel = a.kernel
    chain_mnemonics = [kernel.instructions[i].mnemonic
                       for i in sorted(a.lcd.on_longest)]
    assert len(chain_mnemonics) == 12
    assert set(chain_mnemonics) == {"fadd", "fmul"}
    assert chain_mnemonics.count("fmul") == 4


def test_tx2_cp_includes_store_load_segment(analyses):
    """Table II CP column: the str->ldr writeback segment is on the CP."""
    a = analyses["tx2"]
    mnems = {a.kernel.instructions[i].mnemonic for i in a.cp.on_path}
    assert "str" in mnems and "ldr" in mnems


def test_report_renders(analyses):
    rep = analyses["tx2"].report()
    assert "per high-level iteration" in rep
    assert " 72.0" in rep and "100.0" in rep
