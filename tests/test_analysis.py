"""Unit + property tests for TP / CP / LCD on synthetic kernels with known
answers, plus randomized invariants of the analyses (seeded stdlib ``random``
so the suite has no extra dependencies)."""

import random

import pytest

from repro.core.analysis import (
    analyze_kernel, build_dag, critical_path, loop_carried_dependencies,
    throughput_analysis,
)
from repro.core.isa import parse_aarch64, parse_x86
from repro.core.machine import cascade_lake, thunderx2, zen


def tx2_kernel(body: str):
    return parse_aarch64(f"# OSACA-BEGIN\n{body}\n# OSACA-END")


# -- constructed kernels with known answers -----------------------------------


def test_tp_single_fadd():
    k = tx2_kernel("fadd d0, d1, d2")
    tp = throughput_analysis(k, thunderx2())
    assert tp.block_throughput == pytest.approx(0.5)  # 0.5 cy on P0/P1


def test_tp_many_independent_adds():
    body = "\n".join(f"fadd d{i}, d20, d21" for i in range(8))
    tp = throughput_analysis(tx2_kernel(body), thunderx2())
    assert tp.block_throughput == pytest.approx(4.0)  # 8 * 0.5 per port


def test_cp_serial_chain():
    body = """
fadd d1, d0, d0
fadd d2, d1, d1
fadd d3, d2, d2
"""
    cp = critical_path(tx2_kernel(body), thunderx2())
    assert cp.length == pytest.approx(18.0)  # 3 x lat 6, node-weighted


def test_cp_parallel_chains_takes_longest():
    body = """
fadd d1, d0, d0
fadd d2, d1, d1
fmul d11, d10, d10
"""
    cp = critical_path(tx2_kernel(body), thunderx2())
    assert cp.length == pytest.approx(12.0)


def test_lcd_simple_accumulator():
    k = tx2_kernel("fadd d0, d0, d1")
    lcd = loop_carried_dependencies(k, thunderx2())
    assert lcd.longest == pytest.approx(6.0)


def test_lcd_two_chains_reports_longest():
    body = """
fadd d0, d0, d1
fmul d2, d2, d3
fadd d4, d2, d2
fmul d2, d2, d5
"""
    # d2 chain: fmul(6) -> fmul(6) per iteration = 12; d0 chain = 6.
    lcd = loop_carried_dependencies(tx2_kernel(body), thunderx2())
    assert lcd.longest == pytest.approx(12.0)
    assert len(lcd.chains) >= 2


def test_lcd_none_when_independent():
    body = """
fadd d0, d1, d2
fmul d3, d4, d5
"""
    lcd = loop_carried_dependencies(tx2_kernel(body), thunderx2())
    assert lcd.longest == 0.0


def test_zero_idiom_breaks_dependency():
    body = """
fadd d0, d0, d1
eor x2, x2, x2
"""
    # x2 self-dep broken by the zero idiom: only the d0 chain remains.
    lcd = loop_carried_dependencies(tx2_kernel(body), thunderx2())
    assert all("eor" not in
               [tx2_kernel(body).instructions[i].mnemonic
                for i in c.instr_indices]
               for c in lcd.chains)


def test_memory_operand_split_x86():
    """vaddsd with a memory source = arith pressure + load pressure, and a
    load vertex on the dependency path."""
    asm = """# OSACA-BEGIN
addq $8, %rax
vaddsd (%rax), %xmm1, %xmm2
# OSACA-END"""
    k = parse_x86(asm)
    model = cascade_lake()
    tp = throughput_analysis(k, model)
    assert tp.port_pressure["P2"] == pytest.approx(0.5)  # split load
    cp = critical_path(k, model)
    # addq(1) -> load vertex(6) -> add(4), node-weighted.
    assert cp.length == pytest.approx(11.0)


def test_macro_fusion_csx():
    asm = """# OSACA-BEGIN
cmpq %r13, %rax
jne .L1
# OSACA-END"""
    tp = throughput_analysis(parse_x86(asm), cascade_lake())
    assert tp.port_pressure["P0"] == 0.0  # cmp fused away
    assert tp.port_pressure["P6"] == pytest.approx(1.0)


def test_dag_is_forward_only():
    k = tx2_kernel("""
fadd d1, d0, d0
fadd d2, d1, d1
fadd d1, d2, d2
""")
    dag = build_dag(k, thunderx2(), copies=2)
    for src, succs in enumerate(dag.succs):
        for dst in succs:
            assert dst > src


# -- randomized properties ----------------------------------------------------


def random_fp_kernel(rng: random.Random) -> str:
    """Random TX2 FP kernel text over a small register file."""
    n = rng.randint(2, 12)
    lines = []
    for _ in range(n):
        op = rng.choice(["fadd", "fmul"])
        dst = rng.randint(0, 7)
        a = rng.randint(0, 7)
        b = rng.randint(0, 7)
        lines.append(f"{op} d{dst}, d{a}, d{b}")
    return "\n".join(lines)


def fp_kernel_cases(count: int = 60, seed: int = 0):
    rng = random.Random(seed)
    return [random_fp_kernel(rng) for _ in range(count)]


@pytest.mark.parametrize("body", fp_kernel_cases(60, seed=1))
def test_property_cp_at_least_lcd(body):
    """One period of any cyclic chain is a path in the 1-copy DAG extended by
    the backedge — CP >= LCD for single-block kernels without writebacks."""
    a = analyze_kernel(tx2_kernel(body), thunderx2(), unroll=1)
    assert a.cp_per_it >= a.lcd_per_it - 1e-9


@pytest.mark.parametrize("body", fp_kernel_cases(60, seed=2))
def test_property_tp_lower_bound(body):
    """TP <= CP always (throughput bound cannot exceed the serial bound),
    and TP equals total-pressure max over ports."""
    k = tx2_kernel(body)
    a = analyze_kernel(k, thunderx2(), unroll=1)
    assert a.tp_per_it <= a.cp_per_it + 1e-9
    n_fp = sum(1 for i in k if i.mnemonic in ("fadd", "fmul"))
    assert a.tp_per_it == pytest.approx(n_fp * 0.5)


@pytest.mark.parametrize("body", fp_kernel_cases(60, seed=3))
def test_property_cp_monotone_under_duplication(body):
    """Appending a copy of the body never shortens the critical path."""
    k1 = tx2_kernel(body)
    k2 = tx2_kernel(body + "\n" + body)
    cp1 = critical_path(k1, thunderx2()).length
    cp2 = critical_path(k2, thunderx2()).length
    assert cp2 >= cp1 - 1e-9


@pytest.mark.parametrize("body,reps",
                         [(b, r) for b, r in zip(fp_kernel_cases(40, seed=4),
                                                 [1, 2, 3, 4] * 10)])
def test_property_tp_scales_linearly(body, reps):
    k1 = tx2_kernel(body)
    kn = tx2_kernel("\n".join([body] * reps))
    tp1 = throughput_analysis(k1, thunderx2()).block_throughput
    tpn = throughput_analysis(kn, thunderx2()).block_throughput
    assert tpn == pytest.approx(reps * tp1)


@pytest.mark.parametrize("body", fp_kernel_cases(40, seed=5))
def test_property_lcd_chain_members_form_cycle(body):
    """Every reported chain's members must read a value produced by the
    previous chain member (in cyclic order)."""
    k = tx2_kernel(body)
    lcd = loop_carried_dependencies(k, thunderx2())
    for chain in lcd.chains:
        idxs = list(chain.instr_indices)
        for a, b in zip(idxs, idxs[1:]):
            dsts = set(k.instructions[a].dest_registers)
            srcs = set(k.instructions[b].source_registers)
            assert dsts & srcs, (body, idxs)
