"""Chaos suite for the resilient serving path.

Every failure mode the resilience layer claims to handle is *demonstrated*
here deterministically: virtual clocks instead of sleeps, seeded fault
injection instead of flaky races.  Covers the primitives
(:mod:`repro.serving.resilience`), the injection harness
(:mod:`repro.serving.faults`), and the end-to-end ``AnalysisService``
behavior: every degradation-ladder rung, circuit-breaker transitions,
backpressure shedding, retry/backoff determinism, cache hygiene (degraded or
failed analyses are never cached as full results), and v1 envelope
compatibility.
"""

import threading

import pytest

from repro.core.validation import GS_CLX_ASM, GS_TX2_ASM
from repro.serving.analysis import (API_VERSION, AnalysisRequest,
                                    AnalysisResponse, AnalysisService)
from repro.serving.faults import FaultInjector, InjectedFault, VirtualClock
from repro.serving.resilience import (AdmissionController, CircuitBreaker,
                                      Deadline, ErrorCode, ResilienceConfig,
                                      RetryPolicy, ServingError, StageTimeout,
                                      classify_exception, is_transient,
                                      run_with_deadline)

FULL_STAGES = ("resolve", "tp", "dag", "cp", "lcd", "sim")


def resilient_config(clock, **kw):
    """A ResilienceConfig fully on the virtual clock (no real sleeps)."""
    kw.setdefault("request_timeout_s", 10.0)
    return ResilienceConfig(clock=clock, sleep=clock.sleep, **kw)


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------


def test_classify_exception_taxonomy():
    assert classify_exception(ValueError("unknown arch 'm1'")) == \
        ErrorCode.UNKNOWN_ARCH
    assert classify_exception(ValueError("unknown isa 'martian'")) == \
        ErrorCode.UNKNOWN_ARCH
    assert classify_exception(ValueError("bad operand")) == ErrorCode.PARSE_ERROR
    assert classify_exception(KeyError("fmla")) == ErrorCode.PARSE_ERROR
    assert classify_exception(RuntimeError("boom")) == ErrorCode.INTERNAL
    assert classify_exception(StageTimeout("cp")) == ErrorCode.STAGE_TIMEOUT
    err = ServingError(ErrorCode.OVERLOADED, "full", retryable=True)
    assert classify_exception(err) == ErrorCode.OVERLOADED
    assert is_transient(err) and is_transient(StageTimeout("cp"))
    assert not is_transient(ValueError("bad operand"))


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_deadline_on_virtual_clock():
    clock = VirtualClock()
    d = Deadline.after(1.0, clock)
    assert d.remaining() == pytest.approx(1.0)
    d.check("tp")  # not expired: no raise
    clock.advance(0.5)
    assert not d.expired
    clock.advance(0.5)  # exactly at the deadline counts as expired
    assert d.expired
    with pytest.raises(StageTimeout) as ei:
        d.check("dag")
    assert ei.value.stage == "dag"
    assert ei.value.code == ErrorCode.STAGE_TIMEOUT
    assert ei.value.retryable


def test_run_with_deadline_bounds_a_blocked_worker():
    """A function that blocks *between* cooperative checkpoints is still
    bounded by wall time; the abandoned worker exits once released."""
    release = threading.Event()

    def blocked():
        release.wait()
        return "late"

    try:
        with pytest.raises(StageTimeout) as ei:
            run_with_deadline(blocked, 0.05)
        assert ei.value.stage == "worker"
    finally:
        release.set()  # let the daemonized worker exit
    # Fast paths: results and exceptions relay through.
    assert run_with_deadline(lambda: 42, 5.0) == 42
    with pytest.raises(ValueError, match="boom"):
        run_with_deadline(lambda: (_ for _ in ()).throw(ValueError("boom")),
                          5.0)
    # No/zero timeout runs inline.
    assert run_with_deadline(lambda: "inline", None) == "inline"
    assert run_with_deadline(lambda: "inline", 0.0) == "inline"


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------


def test_backoff_deterministic_and_bounded():
    policy = RetryPolicy(base_delay_s=0.01, multiplier=2.0, max_delay_s=0.05,
                         jitter=0.5)
    a = ResilienceConfig(seed=7).jitter_rng()
    b = ResilienceConfig(seed=7).jitter_rng()
    seq_a = [policy.backoff(i, a) for i in range(8)]
    seq_b = [policy.backoff(i, b) for i in range(8)]
    assert seq_a == seq_b  # same seed -> bit-identical schedule
    for i, delay in enumerate(seq_a):
        nominal = min(0.01 * 2.0 ** i, 0.05)
        assert 0.5 * nominal <= delay <= 1.5 * nominal
    # A different seed jitters differently.
    c = ResilienceConfig(seed=8).jitter_rng()
    assert [policy.backoff(i, c) for i in range(8)] != seq_a
    # Without jitter the schedule is the pure clipped exponential.
    plain = RetryPolicy(base_delay_s=0.01, multiplier=2.0, max_delay_s=0.05,
                        jitter=0.0)
    assert [plain.backoff(i, a) for i in range(4)] == \
        [0.01, 0.02, 0.04, 0.05]


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


def test_breaker_full_transition_cycle():
    clock = VirtualClock()
    br = CircuitBreaker(failure_threshold=3, reset_timeout_s=5.0, clock=clock)
    assert br.state == CircuitBreaker.CLOSED
    br.record_failure()
    br.record_failure()
    assert br.state == CircuitBreaker.CLOSED and br.allow()
    br.record_success()  # consecutive-failure counter resets
    br.record_failure()
    br.record_failure()
    br.record_failure()
    assert br.state == CircuitBreaker.OPEN
    assert not br.allow()
    assert br.retry_after() == pytest.approx(5.0)
    clock.advance(2.0)
    assert br.retry_after() == pytest.approx(3.0)
    clock.advance(3.0)  # timer elapses: half-open, exactly one probe
    assert br.state == CircuitBreaker.HALF_OPEN
    assert br.allow()
    assert not br.allow()  # second concurrent probe rejected
    br.record_success()
    assert br.state == CircuitBreaker.CLOSED and br.allow()


def test_breaker_failed_probe_reopens():
    clock = VirtualClock()
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=5.0, clock=clock)
    br.record_failure()
    assert br.state == CircuitBreaker.OPEN
    clock.advance(5.0)
    assert br.allow()  # the half-open probe
    br.record_failure()  # probe fails: back to OPEN, timer restarted
    assert br.state == CircuitBreaker.OPEN
    assert br.retry_after() == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_bounded_and_unbounded():
    adm = AdmissionController(max_depth=3, retry_after_s=0.25)
    assert adm.try_acquire(2) == 2
    assert adm.try_acquire(4) == 1  # only one slot left
    assert adm.shed_total == 3
    assert adm.try_acquire(1) == 0
    adm.release(3)
    assert adm.try_acquire(2) == 2
    err = adm.overload_error()
    assert err.code == ErrorCode.OVERLOADED and err.retryable
    assert err.retry_after_s == 0.25
    unbounded = AdmissionController(max_depth=0)
    assert unbounded.try_acquire(1000) == 1000
    assert unbounded.shed_total == 0


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------


def test_fault_injector_seeded_determinism():
    # Two injectors with the same seed fire on exactly the same calls.
    a = FaultInjector(seed=42, rates={"stage:cp": 0.3})
    b = FaultInjector(seed=42, rates={"stage:cp": 0.3})
    seq_a = [a.should_fire("stage:cp") for _ in range(200)]
    seq_b = [b.should_fire("stage:cp") for _ in range(200)]
    assert seq_a == seq_b
    assert 0 < sum(seq_a) < 200  # the rate actually does something
    assert a.calls["stage:cp"] == 200
    assert a.fired["stage:cp"] == sum(seq_a)
    # A different seed gives a different (still deterministic) pattern.
    c = FaultInjector(seed=43, rates={"stage:cp": 0.3})
    assert [c.should_fire("stage:cp") for _ in range(200)] != seq_a
    # Per-site streams are independent: adding a second site does not
    # perturb the first one's firing pattern.
    d = FaultInjector(seed=42, rates={"stage:cp": 0.3, "parse": 0.9})
    seq_d = []
    for _ in range(200):
        d.should_fire("parse")
        seq_d.append(d.should_fire("stage:cp"))
    assert seq_d == seq_a


def test_fault_injector_scripts_and_unspecced_sites():
    inj = FaultInjector(seed=0, scripts={"parse": {2, 5}})
    fires = [inj.should_fire("parse") for _ in range(6)]
    assert fires == [False, True, False, False, True, False]
    # Unspecced sites never fire but are still counted (reach assertions).
    assert not any(inj.should_fire("stage:dag") for _ in range(10))
    assert inj.calls["stage:dag"] == 10
    with pytest.raises(InjectedFault) as ei:
        FaultInjector(scripts={"parse": {1}}).check("parse")
    assert ei.value.code == ErrorCode.PARSE_ERROR and ei.value.retryable
    with pytest.raises(InjectedFault) as ei:
        FaultInjector(scripts={"stage:cp": {1}}, transient=False) \
            .check("stage:cp")
    assert ei.value.code == ErrorCode.INTERNAL and not ei.value.retryable


def test_fault_injector_timeout_site_advances_virtual_clock():
    clock = VirtualClock()
    inj = FaultInjector(scripts={"timeout:dag": {1}}, clock=clock,
                        advance_s=7.0)
    inj.check("timeout:dag")  # no raise: the clock jumps instead
    assert clock.now == pytest.approx(7.0)
    # Without a clock attached the site degenerates to raising.
    bare = FaultInjector(scripts={"timeout:dag": {1}})
    with pytest.raises(InjectedFault) as ei:
        bare.check("timeout:dag")
    assert ei.value.code == ErrorCode.STAGE_TIMEOUT


# ---------------------------------------------------------------------------
# service: degradation ladder, end to end
# ---------------------------------------------------------------------------


def test_service_full_rung_matches_plain_path():
    """With resilience on but nothing going wrong, the answer is the plain
    path's answer — bit-identical report, no degradation, one attempt."""
    clock = VirtualClock()
    plain = AnalysisService()
    resilient = AnalysisService(resilience=resilient_config(clock))
    req = AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", unroll=4, name="gs")
    a = plain.submit(req)
    b = resilient.submit(req)
    assert a.ok and b.ok
    assert not b.degraded and b.error_code == "" and b.attempts == 1
    assert b.stages_completed == FULL_STAGES
    assert a.report.to_dict() == b.report.to_dict()
    assert clock.sleeps == []  # no retries -> no backoff waits


def test_service_degrades_to_tp_only_on_persistent_cp_fault():
    """A stage fault that survives every retry drops the job one rung: the
    answer is the optimistic-TP-only analysis, marked DEGRADED."""
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock),
        faults=FaultInjector(seed=0, rates={"stage:cp": 1.0}))
    resp = service.submit(
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", unroll=4, name="gs"))
    assert resp.ok and resp.degraded
    assert resp.error_code == ErrorCode.DEGRADED
    assert resp.stages_completed == ("resolve", "tp")
    assert resp.report.degraded and resp.report.degradation == "tp_only"
    assert resp.report.tp_block > 0  # the optimistic bound still answers
    # 3 attempts at full + 3 at bracket (both rungs run cp, all fault
    # there) + 1 at tp_only (no cp stage).
    assert resp.attempts == 7
    assert service.counters["retries"] == 4
    assert service.counters["degraded"] == 1
    assert service.counters["faults_injected"] == 6
    assert len(clock.sleeps) == 4  # backoffs were simulated, not slept


def test_service_degrades_to_parse_only_on_deadline_blowout():
    """An injected timeout advances the virtual clock past the request
    deadline; the *real* deadline machinery trips at the stage boundary and
    the ladder falls to the always-answers parse-only rung."""
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock),
        faults=FaultInjector(seed=0, rates={"timeout:dag": 1.0}, clock=clock,
                             advance_s=3600.0))
    resp = service.submit(
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", unroll=4, name="gs"))
    assert resp.ok and resp.degraded
    assert resp.report.degradation == "parse_only"
    assert resp.stages_completed == ()
    assert resp.report.rows  # parse-level rows still present
    assert resp.report.tp_block == 0.0  # no numbers were computed
    # full timed out; bracket's and tp_only's first checkpoints saw the
    # dead deadline; parse_only answered without checkpoints.
    assert resp.attempts == 4


def test_service_min_rung_full_errors_instead_of_degrading():
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock, min_rung="full"),
        faults=FaultInjector(seed=0, rates={"stage:tp": 1.0}))
    resp = service.submit(
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", name="gs"))
    assert not resp.ok and resp.report is None
    assert resp.error_code == ErrorCode.INTERNAL  # injected transient fault
    assert resp.retryable
    assert resp.attempts == 3  # retried, never degraded


def test_service_stage_budget_triggers_degradation():
    """Per-stage budgets: a stage that (virtually) overruns stage_timeout_s
    is caught at the next checkpoint; persistent overruns degrade."""
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock, stage_timeout_s=0.1,
                                    request_timeout_s=100.0),
        faults=FaultInjector(seed=0,
                             scripts={"timeout:dag": set(range(1, 7))},
                             clock=clock, advance_s=0.2))
    resp = service.submit(
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", name="gs"))
    assert resp.ok and resp.degraded
    assert resp.report.degradation == "tp_only"  # tp_only has no dag stage
    assert service.counters["retries"] == 4
    assert clock.sleeps and len(clock.sleeps) == 4


# ---------------------------------------------------------------------------
# service: backpressure + breaker
# ---------------------------------------------------------------------------


def test_service_sheds_load_beyond_queue_depth():
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock, max_queue_depth=2,
                                    retry_after_s=0.25))
    reqs = [AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", name=f"r{i}")
            for i in range(5)]
    responses = service.submit_batch(reqs)
    assert [r.ok for r in responses] == [True, True, False, False, False]
    for shed in responses[2:]:
        assert shed.error_code == ErrorCode.OVERLOADED
        assert shed.retryable and shed.retry_after_s == 0.25
        assert shed.attempts == 0  # never reached the backend
    assert service.counters["shed"] == 3
    # Slots were released at the end of the wave: the next wave is admitted.
    again = service.submit_batch(reqs[:2])
    assert all(r.ok for r in again)


def test_service_breaker_opens_then_recovers():
    """Consecutive backend failures trip the per-arch breaker OPEN; its
    requests are rejected with a retry_after; after the reset timer a probe
    goes through and, succeeding, closes the breaker again."""
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock, min_rung="full",
                                    breaker_failure_threshold=2,
                                    breaker_reset_s=30.0),
        # Exactly the first two jobs' attempts fail (3 retried attempts
        # each); later calls never fire, so the probe can succeed.
        faults=FaultInjector(seed=0, scripts={"stage:tp": set(range(1, 7))}))

    def one(name):
        return service.submit(
            AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", name=name))

    assert one("j1").error_code == ErrorCode.INTERNAL  # failure 1
    assert one("j2").error_code == ErrorCode.INTERNAL  # failure 2 -> OPEN
    rejected = one("j3")
    assert rejected.error_code == ErrorCode.OVERLOADED
    assert "circuit breaker open" in rejected.error
    assert rejected.retryable and rejected.retry_after_s == pytest.approx(30.0)
    assert rejected.attempts == 0
    assert service.counters["breaker_rejected"] == 1
    assert service.breaker_for("tx2").state == CircuitBreaker.OPEN

    clock.advance(30.0)  # reset timer elapses: half-open
    probe = one("j4")  # the single probe; faults are exhausted -> succeeds
    assert probe.ok and not probe.degraded
    assert service.breaker_for("tx2").state == CircuitBreaker.CLOSED
    assert one("j5").ok  # traffic flows again (served from cache, even)


def test_service_degraded_answer_counts_as_breaker_failure():
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock, breaker_failure_threshold=2),
        faults=FaultInjector(seed=0, rates={"stage:cp": 1.0}))
    for i in range(2):
        resp = service.submit(AnalysisRequest(
            asm=GS_TX2_ASM, arch="tx2", name=f"d{i}"))
        assert resp.ok and resp.degraded  # answered, but degraded
    # Two forced degradations = two backend failures: breaker is OPEN.
    assert service.breaker_for("tx2").state == CircuitBreaker.OPEN
    assert service.submit(AnalysisRequest(
        asm=GS_TX2_ASM, arch="tx2", name="d2")).error_code == \
        ErrorCode.OVERLOADED


def test_service_client_errors_do_not_trip_breaker():
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock, breaker_failure_threshold=1),
        # A *permanent* parse failure: the caller's malformed kernel.
        faults=FaultInjector(seed=0, scripts={"parse": {1}},
                             transient=False))
    bad = service.submit(AnalysisRequest(asm=GS_TX2_ASM, arch="tx2",
                                         name="bad"))
    assert not bad.ok and bad.error_code == ErrorCode.PARSE_ERROR
    assert not bad.retryable
    # The caller's malformed kernel is not the backend's failure.
    assert service.breaker_for("tx2").state == CircuitBreaker.CLOSED
    # Unknown archs are client errors too: no breaker, no trip.
    unknown = service.submit(AnalysisRequest(asm="x", arch="not-a-machine"))
    assert unknown.error_code == ErrorCode.UNKNOWN_ARCH
    assert not unknown.retryable


# ---------------------------------------------------------------------------
# service: cache hygiene under faults
# ---------------------------------------------------------------------------


def test_degraded_results_are_never_cached():
    """Satellite guarantee: a degraded answer is served but *not* stored —
    once the fault clears, the same request gets the full report again."""
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock),
        faults=FaultInjector(seed=0, rates={"stage:cp": 1.0}))
    req = AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", unroll=4, name="gs")
    first = service.submit(req)
    assert first.degraded
    service.faults = None  # the "outage" ends
    second = service.submit(req)
    assert second.ok and not second.degraded
    assert second.stages_completed == FULL_STAGES
    # Nothing degraded was ever served from cache (misses count cache
    # *insertions*: only the second, full answer was stored).
    assert service.stats["hits"] == 0 and service.stats["misses"] == 1
    # The now-cached entry is the full result.
    third = service.submit(req)
    assert third.ok and not third.degraded
    assert service.stats["hits"] == 1


def test_transient_errors_are_not_negative_cached():
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock),
        faults=FaultInjector(seed=0, scripts={"parse": {1}}))
    req = AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", name="gs")
    first = service.submit(req)
    assert not first.ok and first.retryable  # injected transient parse fault
    second = service.submit(req)  # script exhausted: the retry succeeds
    assert second.ok and not second.degraded
    assert service.stats["hits"] == 0  # the error was never served from cache


def test_permanent_errors_are_negative_cached():
    clock = VirtualClock()
    faults = FaultInjector(seed=0, scripts={"parse": {1}}, transient=False)
    service = AnalysisService(resilience=resilient_config(clock),
                              faults=faults)
    req = AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", name="bad")
    first = service.submit(req)
    assert not first.ok and not first.retryable
    assert first.error_code == ErrorCode.PARSE_ERROR
    second = service.submit(req)  # script exhausted, but the error is cached
    assert not second.ok and second.error_code == first.error_code
    assert service.stats["hits"] == 1  # served from the negative cache
    assert faults.calls["parse"] == 1  # never re-parsed


def test_cache_eviction_fault_forces_reanalysis():
    clock = VirtualClock()
    faults = FaultInjector(seed=0, scripts={"cache": {2}})
    service = AnalysisService(resilience=resilient_config(clock),
                              faults=faults)
    req = AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", name="gs")
    assert service.submit(req).ok
    assert service.submit(req).ok  # eviction fired: recomputed, same answer
    assert faults.fired.get("cache") == 1
    assert service.stats["misses"] == 2 and service.stats["hits"] == 0
    assert service.submit(req).ok
    assert service.stats["hits"] == 1  # back to normal caching


# ---------------------------------------------------------------------------
# wire contract
# ---------------------------------------------------------------------------


def test_v1_envelopes_still_parse():
    """PR-2 (v1) payloads predate the taxonomy fields; they must round-trip
    with sensible defaults."""
    v1_err = {"version": 1, "ok": False, "name": "k", "arch": "tx2",
              "error": "ValueError: bad operand", "report": None}
    resp = AnalysisResponse.from_dict(v1_err)
    assert not resp.ok
    assert resp.error_code == ErrorCode.INTERNAL  # default for v1 errors
    assert resp.error == "ValueError: bad operand"  # free text preserved
    assert not resp.retryable and not resp.degraded
    v1_ok = {"version": 1, "ok": True, "name": "k", "arch": "tx2",
             "error": "", "report": None}
    ok = AnalysisResponse.from_dict(v1_ok)
    assert ok.ok and ok.error_code == "" and ok.attempts == 1
    v1_req = {"asm": "fadd d0, d0, d1", "arch": "tx2"}
    req = AnalysisRequest.from_dict(v1_req)
    assert req.timeout_s == 0.0 and req.version == API_VERSION


def test_v2_envelope_roundtrip_with_degradation():
    clock = VirtualClock()
    service = AnalysisService(
        resilience=resilient_config(clock),
        faults=FaultInjector(seed=0, rates={"stage:cp": 1.0}))
    resp = service.submit(
        AnalysisRequest(asm=GS_TX2_ASM, arch="tx2", name="gs"))
    wire = resp.to_dict()
    assert wire["version"] == API_VERSION
    back = AnalysisResponse.from_dict(wire)
    assert back.degraded and back.error_code == ErrorCode.DEGRADED
    assert back.stages_completed == resp.stages_completed
    assert back.report.to_dict() == resp.report.to_dict()


def test_request_timeout_excluded_from_cache_key():
    a = AnalysisRequest(asm=GS_CLX_ASM, arch="csx", timeout_s=0.5)
    b = AnalysisRequest(asm=GS_CLX_ASM, arch="cascadelake", timeout_s=2.0)
    assert a.key == b.key  # alias-canonical and timeout-blind


# ---------------------------------------------------------------------------
# facade: analyze(..., timeout_s=, degrade=)
# ---------------------------------------------------------------------------


def test_api_analyze_degrades_on_expired_deadline():
    from repro.api import analyze

    report = analyze(GS_TX2_ASM, arch="tx2", timeout_s=0.0, degrade=True)
    assert report.degraded and report.degradation == "parse_only"
    assert report.rows


def test_api_analyze_raises_without_degrade():
    from repro.api import analyze

    with pytest.raises(StageTimeout):
        analyze(GS_TX2_ASM, arch="tx2", timeout_s=0.0)


def test_api_analyze_under_generous_deadline_is_bit_identical():
    from repro.api import analyze

    plain = analyze(GS_TX2_ASM, arch="tx2", unroll=4)
    bounded = analyze(GS_TX2_ASM, arch="tx2", unroll=4, timeout_s=60.0,
                      degrade=True)
    assert not bounded.degraded
    assert bounded.to_dict() == plain.to_dict()
