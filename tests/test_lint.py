"""Machine-DB / registry linter tests: the shipped tables pass ``--strict``,
and each check fires on a purposely corrupted table (the CI-gate guarantee:
a typo'd port or latency fails the build, it does not silently skew bounds)."""

import dataclasses

import pytest

from repro.core.machine import (cascade_lake, neoverse_n1, thunderx2, zen,
                                zen2)
from repro.core.machine.lint import (LintIssue, lint_all, lint_model,
                                     lint_registry, main)
from repro.core.machine.model import DBEntry, MachineModel, uops_entry
from repro.core.machine.window import WindowParams
from repro.core.registry import registry_snapshot

FACTORIES = (thunderx2, cascade_lake, zen, zen2, neoverse_n1)


def _codes(issues):
    return {i.code for i in issues}


def _with_entry(model: MachineModel, key: str, entry: DBEntry) -> MachineModel:
    db = dict(model.db)
    db[key] = entry
    return dataclasses.replace(model, db=db)


# -- the shipped tables are clean ---------------------------------------------


@pytest.mark.parametrize("factory", FACTORIES, ids=lambda f: f.__name__)
def test_shipped_model_lints_clean_strict(factory):
    assert lint_model(factory()) == []


def test_shipped_registry_lints_clean():
    assert lint_registry() == []


def test_lint_all_clean_and_cli_exit_codes(capsys):
    assert lint_all() == []
    assert main(["--strict"]) == 0
    out = capsys.readouterr().out
    assert "0 error(s), 0 warning(s)" in out
    # A subset run names only the requested archs.
    assert main(["tx2", "--strict"]) == 0
    assert "1 machine DB(s)" in capsys.readouterr().out


# -- per-entry checks fire on corrupted DBs -----------------------------------


def test_negative_latency_and_undeclared_port_fail():
    bad = _with_entry(thunderx2(), "badinst",
                      DBEntry(latency=-3.0, pressure={"P9": 0.5}))
    issues = lint_model(bad)
    assert {"NEGATIVE_LATENCY", "UNDECLARED_PORT"} <= _codes(issues)
    assert all(i.severity == "error" for i in issues)
    assert any(i.subject == "badinst" for i in issues)


def test_nan_latency_is_an_error():
    bad = _with_entry(thunderx2(), "naninst",
                      DBEntry(latency=float("nan"), pressure={}))
    assert "NEGATIVE_LATENCY" in _codes(lint_model(bad))


def test_implausible_latency_is_warning_only():
    slow = _with_entry(thunderx2(), "slowinst",
                       DBEntry(latency=4000.0, pressure={"P0": 1.0}))
    issues = lint_model(slow)
    assert _codes(issues) == {"IMPLAUSIBLE_LATENCY"}
    assert all(i.severity == "warning" for i in issues)


def test_negative_pressure_and_empty_uop_ports_fail():
    bad = _with_entry(
        thunderx2(), "badp",
        DBEntry(latency=1.0, pressure={"P0": -0.5},
                uops=((1.0, ()),)))
    assert {"NEGATIVE_PRESSURE", "EMPTY_UOP_PORTS"} <= _codes(lint_model(bad))


def test_uop_pressure_mismatch_fails():
    # Stored uniform split says P0-only, but the µ-op is P0/P1-eligible.
    lying = DBEntry(latency=1.0, pressure={"P0": 1.0},
                    uops=((1.0, ("P0", "P1")),))
    issues = lint_model(_with_entry(thunderx2(), "liar", lying))
    assert "UOP_PRESSURE_MISMATCH" in _codes(issues)
    # The honest derivation (0.5/0.5) passes.
    honest = uops_entry(1.0, [(1.0, ("P0", "P1"))])
    assert lint_model(_with_entry(thunderx2(), "liar", honest)) == []


def test_throughput_inconsistent_fails():
    # One 2-cy µ-op pinned to P0 cannot beat 2 cy inverse throughput.
    entry = dataclasses.replace(uops_entry(4.0, [(2.0, ("P0",))]),
                                throughput=0.5)
    issues = lint_model(_with_entry(thunderx2(), "tooGood", entry))
    assert "THROUGHPUT_INCONSISTENT" in _codes(issues)
    ok = dataclasses.replace(entry, throughput=2.0)
    assert lint_model(_with_entry(thunderx2(), "tooGood", ok)) == []


# -- model-level checks -------------------------------------------------------


def test_duplicate_port_and_missing_entry_fail():
    model = thunderx2()
    dup = dataclasses.replace(model, ports=model.ports + ("P0",))
    assert "DUPLICATE_PORT" in _codes(lint_model(dup))
    gutted = dataclasses.replace(model, load_entry=None)
    assert "MISSING_ENTRY" in _codes(lint_model(gutted))


def test_window_bounds_violation_fails():
    # Constructor does not validate; the linter must catch the bypass.
    bad_window = WindowParams(issue_width=8, rob_size=4, sched_size=60,
                              lsq_size=36, retire_width=4)
    model = dataclasses.replace(thunderx2(), window=bad_window)
    issues = lint_model(model)
    assert "WINDOW_BOUNDS" in _codes(issues)
    no_window = dataclasses.replace(thunderx2(), window=None)
    warnings_ = [i for i in lint_model(no_window) if i.code == "NO_WINDOW"]
    assert warnings_ and warnings_[0].severity == "warning"


def test_fusion_without_pressure_warns():
    model = dataclasses.replace(cascade_lake(), fused_branch_pressure={})
    issues = [i for i in lint_model(model) if i.code == "FUSION_NO_PRESSURE"]
    assert issues and issues[0].severity == "warning"


def test_bad_frequency_fails():
    model = dataclasses.replace(thunderx2(), frequency_ghz=0.0)
    assert "BAD_FREQUENCY" in _codes(lint_model(model))


# -- registry checks (injected tables) ----------------------------------------


def test_alias_cycle_and_dangling_alias_fire():
    issues = lint_registry(names={"a": "B", "b": "A"}, registry={})
    assert _codes(issues) == {"ALIAS_CYCLE"}
    issues = lint_registry(names={"a": "ghost"}, registry={})
    assert _codes(issues) == {"DANGLING_ALIAS"}


def test_self_resolution_fires():
    names, registry = registry_snapshot()
    names["tx2"] = "csx"  # copies: the live registry is untouched
    issues = lint_registry(names=names, registry=registry)
    assert any(i.code == "SELF_RESOLUTION" and i.subject == "tx2"
               for i in issues)
    assert lint_registry() == []  # live tables unharmed


def test_no_parser_fires():
    names, registry = registry_snapshot()
    spec = dataclasses.replace(registry["tx2"], parser=None)
    registry["tx2"] = spec
    issues = lint_registry(names=names, registry=registry)
    assert any(i.code == "NO_PARSER" and i.subject == "tx2" for i in issues)


# -- CLI ----------------------------------------------------------------------


def test_cli_fails_on_corrupted_db(monkeypatch, capsys):
    import repro.core.machine.lint as lint_mod

    def corrupt_all(arch_ids=None):
        return [LintIssue("error", "tx2", "NEGATIVE_LATENCY", "badinst",
                          "latency -3.0 is not a non-negative number")]

    monkeypatch.setattr(lint_mod, "lint_all", corrupt_all)
    assert main([]) == 1
    out = capsys.readouterr().out
    assert "NEGATIVE_LATENCY" in out and "1 error(s)" in out


def test_cli_strict_fails_on_warning(monkeypatch, capsys):
    import repro.core.machine.lint as lint_mod

    def warn_all(arch_ids=None):
        return [LintIssue("warning", "tx2", "IMPLAUSIBLE_LATENCY", "slow",
                          "latency 4000 cy exceeds the plausibility cap")]

    monkeypatch.setattr(lint_mod, "lint_all", warn_all)
    assert main([]) == 0  # warnings alone pass the default gate
    assert main(["--strict"]) == 1
    assert "1 warning(s)" in capsys.readouterr().out
