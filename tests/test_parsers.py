"""Unit tests for the x86 (AT&T) and AArch64 assembly front-ends."""

from repro.core.isa import parse_aarch64, parse_x86
from repro.core.isa.instruction import MemoryRef, Register
from repro.core.isa.parser_aarch64 import parse_line_aarch64
from repro.core.isa.parser_x86 import parse_line_x86


# -- AArch64 -----------------------------------------------------------------


def test_a64_load_indexed():
    f = parse_line_aarch64("ldr d31, [x15, x18, lsl 3]")
    assert f.mnemonic == "ldr"
    assert f.dest_registers == ("v31",)
    assert set(f.source_registers) == {"x15", "x18"}
    assert f.loads[0].scale == 8


def test_a64_store_post_index_writeback():
    f = parse_line_aarch64("str d5, [x14], 8")
    assert f.stores[0].post_index
    assert "x14" in f.dest_registers  # writeback
    assert "v5" in f.source_registers


def test_a64_fp_three_operand():
    f = parse_line_aarch64("fadd d3, d1, d30")
    assert f.dest_registers == ("v3",)
    assert set(f.source_registers) == {"v1", "v30"}


def test_a64_register_aliasing():
    f = parse_line_aarch64("fmov s2, s3")
    assert f.dest_registers == ("v2",)  # s2 aliases v2


def test_a64_branch_and_cmp():
    b = parse_line_aarch64("bne .L20")
    assert b.is_branch and not b.dest_registers
    c = parse_line_aarch64("cmp x7, x15")
    assert not c.dest_registers
    assert set(c.source_registers) == {"x7", "x15"}


def test_a64_zero_idiom():
    f = parse_line_aarch64("eor x3, x3, x3")
    assert f.is_dep_breaking
    assert f.source_registers == ()


def test_a64_negative_offset():
    f = parse_line_aarch64("str d20, [x15, -24]")
    assert f.stores[0].offset == -24


def test_a64_ldp_writes_both_registers():
    f = parse_line_aarch64("ldp x0, x1, [sp]")
    assert f.dest_registers == ("x0", "x1")
    assert "x1" not in f.source_registers  # x1 is a dest, not a source
    assert f.source_registers == ("sp",)
    assert f.loads


def test_a64_ldp_post_index_writeback():
    f = parse_line_aarch64("ldp d0, d1, [x2], 16")
    assert f.dest_registers == ("v0", "v1", "x2")
    assert f.loads[0].post_index


def test_a64_ld2_structure_list_dests():
    f = parse_line_aarch64("ld2 {v0.2d, v1.2d}, [x0]")
    assert f.dest_registers == ("v0", "v1")
    assert f.source_registers == ("x0",)
    assert f.operand_signature() == "vvm"


def test_a64_zero_register_no_dependencies():
    # Reads of xzr/wzr are constant zero, not register sources.
    f = parse_line_aarch64("mov x3, xzr")
    assert f.dest_registers == ("x3",)
    assert f.source_registers == ()
    # Writes to the zero register are discarded: no def, no edges.
    f = parse_line_aarch64("subs wzr, x1, x2")  # cmp alias
    assert f.dest_registers == ()
    assert set(f.source_registers) == {"x1", "x2"}
    # Still parsed as a register so DB signatures stay stable.
    assert f.operand_signature() == "rrr"


def test_a64_zero_register_breaks_dag_chains():
    from repro.core.analysis import build_dag
    from repro.core.machine import thunderx2

    kernel = parse_aarch64(
        "# OSACA-BEGIN\nadd xzr, x1, x2\nadd x3, xzr, x4\n# OSACA-END")
    dag = build_dag(kernel, thunderx2())
    # No def-use edge flows through the zero register.
    assert all(not preds for preds in dag.preds)


# -- x86 ----------------------------------------------------------------------


def test_x86_avx_three_operand():
    f = parse_line_x86("vaddsd %xmm0, %xmm4, %xmm5")
    assert f.dest_registers == ("xmm5",)
    assert set(f.source_registers) == {"xmm0", "xmm4"}


def test_x86_sse_two_operand_rmw():
    f = parse_line_x86("addsd %xmm1, %xmm2")
    assert f.dest_registers == ("xmm2",)
    assert set(f.source_registers) == {"xmm1", "xmm2"}  # RMW reads dest


def test_x86_mov_not_rmw():
    f = parse_line_x86("movsd %xmm1, %xmm2")
    assert f.source_registers == ("xmm1",)


def test_x86_load_base_index_scale():
    f = parse_line_x86("movsd -8(%rsi,%rbx,8), %xmm1")
    assert f.dest_registers == ("xmm1",)
    assert f.loads[0].offset == -8
    assert f.loads[0].scale == 8
    assert set(f.source_registers) == {"rsi", "rbx"}


def test_x86_store():
    f = parse_line_x86("movsd %xmm0, 16(%rax,%rbx,8)")
    assert not f.dest_registers
    assert f.stores and f.stores[0].offset == 16


def test_x86_sub_register_aliasing():
    f = parse_line_x86("movl %eax, %edx")
    assert f.dest_registers == ("rdx",)
    assert f.source_registers == ("rax",)


def test_x86_immediate_rmw():
    f = parse_line_x86("addq $32, %rax")
    assert f.dest_registers == ("rax",)
    assert "rax" in f.source_registers


def test_x86_zero_idiom():
    f = parse_line_x86("vxorpd %xmm0, %xmm0, %xmm0")
    assert f.is_dep_breaking and f.source_registers == ()


def test_x86_ymm_aliases_xmm():
    f = parse_line_x86("vaddpd %ymm1, %ymm2, %ymm3")
    assert f.dest_registers == ("xmm3",)


def test_x86_lea_is_not_a_load():
    f = parse_line_x86("leaq 8(%rax,%rbx,4), %rcx")
    assert f.loads == ()  # pure address arithmetic: no load µ-op
    assert f.dest_registers == ("rcx",)
    assert set(f.source_registers) == {"rax", "rbx"}
    assert f.operand_signature() == "mr"  # DB keys (leaq:mr) unchanged


def test_x86_lea_no_phantom_load_vertex():
    from repro.core.analysis import build_dag
    from repro.core.machine import cascade_lake

    asm = ("# OSACA-BEGIN\nleaq (%rax,%rbx,8), %rcx\n"
           "addq %rcx, %rdx\n# OSACA-END")
    dag = build_dag(parse_x86(asm), cascade_lake())
    assert [n.kind for n in dag.nodes] == ["instr", "instr"]
    # lea -> add dependency flows through rcx with lea's 1-cycle latency.
    assert dag.preds[1] == [0]
    assert dag.nodes[0].latency == 1.0


def test_x86_byte_register_aliases():
    # sil/dil/bpl/spl used to fall through to Label, losing dependencies.
    f = parse_line_x86("movb %sil, %dil")
    assert f.dest_registers == ("rdi",)
    assert f.source_registers == ("rsi",)
    assert [op.width for op in f.operands] == [8, 8]
    f = parse_line_x86("addb %bpl, %spl")
    assert f.dest_registers == ("rsp",)
    assert set(f.source_registers) == {"rbp", "rsp"}  # RMW reads dest


def test_x86_subregister_widths():
    assert [op.width for op in parse_line_x86("movb %al, %bl").operands] == [8, 8]
    assert [op.width for op in parse_line_x86("movw %ax, %bx").operands] == [16, 16]
    assert [op.width for op in parse_line_x86("movl %eax, %edx").operands] == [32, 32]
    assert [op.width for op in parse_line_x86("movq %rax, %rdx").operands] == [64, 64]
    f = parse_line_x86("movw %r8w, %r9w")
    assert f.dest_registers == ("r9",) and f.operands[0].width == 16


# -- marker extraction ---------------------------------------------------------


def test_marker_extraction_osaca_comments():
    asm = """
    nop
# OSACA-BEGIN
    fadd d0, d1, d2
# OSACA-END
    nop
"""
    k = parse_aarch64(asm)
    assert len(k) == 1 and k.instructions[0].mnemonic == "fadd"


def test_marker_extraction_iaca_bytes():
    asm = """
    movl $111, %ebx
    .byte 100,103,144
    vaddsd %xmm0, %xmm1, %xmm2
    movl $222, %ebx
    .byte 100,103,144
"""
    k = parse_x86(asm)
    assert [i.mnemonic for i in k] == ["vaddsd"]


def test_marker_fallback_innermost_loop():
    asm = """
.L1:
    fadd d0, d0, d1
    bne .L1
"""
    k = parse_aarch64(asm)
    assert [i.mnemonic for i in k] == ["fadd", "bne"]
